"""Semantic latent cache (ISSUE 7): bank coherence with the exact LRU,
selection parity vs bit_exact mode, persistence round-trips, and the
serving-log replay warm-up.

The load-bearing contract, asserted per policy on the full demo corpus:
``mode="semantic"`` produces selections IDENTICAL to ``mode="bit_exact"``
(and to the bare router) while reporting a strictly higher combined hit
rate on near-duplicate traffic — the threshold + f32 re-check gate means
int8-quantized latent reuse can never flip a routing decision.
"""
import json
import os

import numpy as np
import pytest

from repro.core.router import POLICIES
from repro.serving import RouterEngine, RouterEngineConfig
from repro.serving.semcache import (LatentBank, RouteLog,
                                    SemanticCacheConfig, _quantize,
                                    latent_fingerprint, load_bank,
                                    save_bank, sketch_batch)


def _skewed_stream(world, seed=0, n=192):
    """Near-duplicate-heavy workload: ~50% exact repeats, ~35% one-token
    variants, ~15% fresh OOD texts — the traffic shape the semantic tier
    exists for."""
    from repro.data import OOD_TASKS

    qi = world.query_indices(OOD_TASKS)
    base = [world.queries[i].text for i in qi[:48]]
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        r = rng.random()
        t = base[rng.integers(len(base))]
        if r < 0.50:
            out.append(t)
        elif r < 0.85:
            words = t.split()
            k = int(rng.integers(len(words)))
            words[k] = words[k] + "s"
            out.append(" ".join(words))
        else:
            out.append(t + f" variant {rng.integers(1 << 30)}")
    return out


def _engine(router, mode, cache_size=2048, **kw):
    sc = None if mode is None else SemanticCacheConfig(mode=mode, **kw)
    return RouterEngine(router, RouterEngineConfig(
        cache_size=cache_size, semantic_cache=sc))


# ---------------------------------------------------------------------------
# selection parity + hit accounting (the acceptance contract)
# ---------------------------------------------------------------------------


def test_semantic_matches_bit_exact_all_policies(demo_stack):
    """Per policy, per chunk: semantic selections == bit_exact selections
    == bare-router selections on the skewed stream — and the semantic
    engine's combined hit rate beats the exact-only one."""
    world, router, _ = demo_stack
    stream = _skewed_stream(world, seed=1)
    chunks = [stream[i: i + 64] for i in range(0, len(stream), 64)]
    for pol in POLICIES:
        sem = _engine(router, "semantic")
        bit = _engine(router, "bit_exact")
        for chunk in chunks:
            _, sel_s = sem.route_batch(chunk, policy=pol)
            _, sel_b = bit.route_batch(chunk, policy=pol)
            _, sel_r, _ = router.route(chunk, policy=pol)
            np.testing.assert_array_equal(sel_s, sel_b,
                                          err_msg=f"policy {pol}")
            np.testing.assert_array_equal(sel_s, np.asarray(sel_r),
                                          err_msg=f"policy {pol}")
        ss, sb = sem.cache_stats, bit.cache_stats
        assert ss.semantic_hits > 0, f"policy {pol}: no semantic reuse"
        assert sb.semantic_hits == 0, "bit_exact must never probe"
        assert ss.hit_rate > ss.exact_hit_rate
        assert ss.hit_rate > sb.hit_rate, \
            f"policy {pol}: combined {ss.hit_rate:.3f} <= " \
            f"exact {sb.hit_rate:.3f}"


def test_int8_storage_matches_f32_storage_selections(demo_stack):
    """The quantization-parity satellite: int8 at-rest storage (default)
    and full-f32 storage route identically — the gate absorbs the ~2e-3
    dequantization error before it can reach a decision."""
    world, router, _ = demo_stack
    stream = _skewed_stream(world, seed=2)
    e8 = _engine(router, "semantic", store="int8")
    e32 = _engine(router, "semantic", store="f32")
    for i in range(0, len(stream), 64):
        chunk = stream[i: i + 64]
        _, sel8 = e8.route_batch(chunk)
        _, sel32 = e32.route_batch(chunk)
        np.testing.assert_array_equal(sel8, sel32)
    assert e8.cache_stats.semantic_hits > 0


def test_safe_paths_stay_exact(demo_stack):
    """route()/score_queries() (the diagnostics/constrained paths) bypass
    semantic reuse entirely — scores are bit-for-bit the plain engine's
    even with a hot bank."""
    world, router, _ = demo_stack
    stream = _skewed_stream(world, seed=3, n=96)
    sem = _engine(router, "semantic")
    plain = _engine(router, None)
    sem.route_batch(stream)                 # heat the bank
    probe = stream[:24]
    for a, b in zip(sem.score_queries(probe), plain.score_queries(probe)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# coherence: eviction sync, pool mutations, predictor swaps
# ---------------------------------------------------------------------------


def test_bank_evicts_in_sync_with_lru(demo_stack):
    """Cache eviction must free the bank row (bank ⊆ LRU going forward) —
    otherwise an evicted entry keeps serving semantic hits forever."""
    world, router, _ = demo_stack
    engine = _engine(router, "semantic", cache_size=32)
    stream = _skewed_stream(world, seed=4, n=128)
    for i in range(0, len(stream), 32):
        engine.route_batch(stream[i: i + 32])
    assert engine.cache.stats.evictions > 0, "workload must overflow"
    assert engine.bank.evictions > 0
    assert len(engine.bank) <= 32
    for text in engine.bank._rows:
        assert text in engine.cache._data, \
            "bank row survived its LRU entry's eviction"


def test_pool_mutation_respected_by_semantic_hits(demo_stack):
    """Latents are reused, decisions are NOT: after onboarding a model
    mid-traffic, semantic-hit queries route over the NEW pool exactly
    like cold-computed ones (session pool is restored in finally)."""
    from repro.data import ID_TASKS

    world, router, _ = demo_stack
    stream = _skewed_stream(world, seed=5, n=96)
    sem = _engine(router, "semantic")
    sem.route_batch(stream)                 # warm bank on the old pool
    name = "future-model-00"
    m = world.model_index(name)
    anchors = world.query_indices(ID_TASKS)[router.artifacts.anchor_idx]
    try:
        mi = world.models[m]
        lens = world.output_lengths([m], anchors)[0]
        router.onboard(name, world.sample_responses([m], anchors, seed=m)[0],
                       lens, world.true_latency([m], anchors, lens[None])[0],
                       mi.price_in, mi.price_out, mi.tokenizer)
        # FRESH near-duplicates of the same bases: these hit the bank
        # rows banked under the old pool, and must route over the new one
        stream2 = _skewed_stream(world, seed=55, n=96)
        _, sel_sem = sem.route_batch(stream2)
        _, sel_ref, _ = router.route(stream2)
        np.testing.assert_array_equal(sel_sem, np.asarray(sel_ref))
        assert sem.cache_stats.semantic_hits > 0
    finally:
        router.remove(name)


def test_predictor_swap_clears_bank(demo_stack):
    """Swapped artifacts invalidate the banked latents along with the
    LRU — they were computed by the old predictor."""
    world, router, _ = demo_stack
    engine = _engine(router, "semantic")
    engine.route_batch(_skewed_stream(world, seed=6, n=64))
    old_texts = set(engine.bank._rows)
    assert old_texts
    import copy

    art = router.artifacts
    try:
        router.artifacts = copy.copy(art)    # new identity, same weights
        engine.route_batch(["post-swap probe"])
        # the probe itself re-banks post-swap; every OLD row must be gone
        assert not old_texts & set(engine.bank._rows), \
            "stale latents survived the swap"
        assert len(engine.cache._data) == 1
    finally:
        router.artifacts = art


def test_requires_exact_cache_and_valid_mode(demo_stack):
    _, router, _ = demo_stack
    with pytest.raises(ValueError, match="cache_size"):
        RouterEngine(router, RouterEngineConfig(
            cache_size=0, semantic_cache=SemanticCacheConfig()))
    with pytest.raises(ValueError, match="mode"):
        RouterEngine(router, RouterEngineConfig(
            semantic_cache=SemanticCacheConfig(mode="fuzzy")))


# ---------------------------------------------------------------------------
# the bank itself
# ---------------------------------------------------------------------------


def test_quantize_round_trip_error_bound():
    rng = np.random.default_rng(0)
    for _ in range(20):
        x = rng.normal(size=64).astype(np.float32) * rng.uniform(0.1, 8)
        q, scale = _quantize(x)
        err = np.max(np.abs(q.astype(np.float32) * scale - x))
        assert err <= float(scale) / 2 + 1e-7
    q, scale = _quantize(np.zeros(16, np.float32))
    assert float(scale) == 0.0 and not q.any()


def test_bank_overflow_evicts_oldest_and_counts():
    bank = LatentBank(4, 128, 8, store="int8")
    sk = np.zeros(128, np.float32)
    sk[0] = 1.0
    lat = np.arange(8, dtype=np.float32)
    for i in range(6):
        bank.put(f"t{i}", lat, lat, sk)
    assert len(bank) == 4 and bank.evictions == 2
    assert "t0" not in bank and "t1" not in bank and "t5" in bank
    # in-place overwrite neither grows nor evicts
    bank.put("t5", lat + 1, lat + 1, sk)
    assert len(bank) == 4 and bank.evictions == 2
    bank.discard("t5")
    assert len(bank) == 3 and bank.evictions == 3
    bank.discard("never-seen")              # no-op
    assert bank.evictions == 3


def test_exact_duplicate_reads_above_trust_threshold():
    """An int8-stored key probed with its own sketch reads ≥ sim_recheck's
    neighborhood — the property the 0.99 trust band relies on."""
    from repro.core.ingest import lex_batch

    texts = ["the quick brown fox jumps over the lazy dog",
             "compute the eigenvalues of a symmetric 3x3 matrix",
             "translate this sentence into idiomatic french please"]
    sketches = sketch_batch(lex_batch(texts), 128)
    bank = LatentBank(8, 128, 4, store="int8")
    z = np.zeros(4, np.float32)
    for t, sk in zip(texts, sketches):
        bank.put(t, z, z, sk)
    sims, idx = bank.lookup(sketches)
    assert np.all(sims >= 0.995), sims
    for i, t in enumerate(texts):
        assert bank.text_at(int(idx[i])) == t


# ---------------------------------------------------------------------------
# persistence: sidecar round trip, fingerprints, migrations
# ---------------------------------------------------------------------------


def test_sidecar_round_trips_through_router_open(demo_stack, tmp_path):
    """save → open(semantic_cache=True) restores the bank BIT-EXACTLY
    (arrays and text→row mapping), and the reopened engine routes the
    stream identically."""
    world, router, _ = demo_stack
    stream = _skewed_stream(world, seed=7)
    sem = _engine(router, "semantic")
    for i in range(0, len(stream), 64):
        sem.route_batch(stream[i: i + 64])
    assert len(sem.bank) > 0
    art_dir = str(tmp_path / "art")
    router._engine = sem                    # save() persists the sidecar
    try:
        router.save(art_dir)
    finally:
        router._engine = None
    from repro.api import Router

    sc = SemanticCacheConfig(capacity=sem.bank.capacity)
    reopened = Router.open(art_dir, semantic_cache=sc)
    rbank = reopened.engine().bank
    assert reopened.calibration["semcache_restored_rows"] == len(sem.bank)
    assert rbank._rows == sem.bank._rows
    for field in ("keys", "key_scale", "a", "a_scale", "b", "b_scale",
                  "valid"):
        np.testing.assert_array_equal(getattr(rbank, field),
                                      getattr(sem.bank, field),
                                      err_msg=field)
    _, sel_new = reopened.engine().route_batch(stream[:64])
    _, sel_old, _ = router.route(stream[:64])
    np.testing.assert_array_equal(sel_new, np.asarray(sel_old))


def test_stale_fingerprint_starts_cold_with_warning(demo_stack, tmp_path):
    world, router, _ = demo_stack
    sem = _engine(router, "semantic")
    sem.route_batch(_skewed_stream(world, seed=8, n=64))
    d = str(tmp_path)
    save_bank(d, sem.bank, "0123456789abcdef")
    real = latent_fingerprint(router.artifacts)
    assert real != "0123456789abcdef"
    with pytest.warns(UserWarning, match="fingerprint"):
        assert load_bank(d, SemanticCacheConfig(), real) is None
    # matching fingerprint restores
    save_bank(d, sem.bank, real)
    bank = load_bank(d, SemanticCacheConfig(), real)
    assert bank is not None and len(bank) == len(sem.bank)
    # layout mismatch also rejects
    with pytest.warns(UserWarning, match="layout"):
        assert load_bank(d, SemanticCacheConfig(sketch_dim=64), real) is None


def test_sidecar_rides_the_artifact_migration_chain(tmp_path):
    """A sidecar stamped with an older container schema_version loads
    through a registered migration step — the record is a first-class
    artifact, not a bespoke format."""
    from repro.checkpoint.ckpt import (_ARTIFACT_MIGRATIONS,
                                       register_artifact_migration)

    bank = LatentBank(4, 128, 8)
    sk = np.zeros(128, np.float32)
    sk[3] = 1.0
    bank.put("hello", np.ones(8, np.float32), np.ones(8, np.float32), sk)
    d = str(tmp_path)
    save_bank(d, bank, "fp")
    meta_path = os.path.join(d, "semcache.meta.json")
    with open(meta_path) as f:
        rec = json.load(f)
    rec["schema_version"] = 0
    with open(meta_path, "w") as f:
        json.dump(rec, f)
    # without a migration: cold start (warns), never a crash
    with pytest.warns(UserWarning):
        assert load_bank(d, SemanticCacheConfig(), "fp") is None
    calls = []

    @register_artifact_migration(0)
    def _v0_to_v1(pair):
        tree, meta = pair
        calls.append(1)
        return tree, meta

    try:
        restored = load_bank(d, SemanticCacheConfig(), "fp")
        assert calls and restored is not None and "hello" in restored
    finally:
        _ARTIFACT_MIGRATIONS.pop(0)


def test_from_state_rebeds_into_smaller_capacity():
    bank = LatentBank(8, 128, 4)
    sk = np.zeros(128, np.float32)
    sk[1] = 1.0
    for i in range(6):
        bank.put(f"q{i}", np.full(4, i, np.float32),
                 np.full(4, -i, np.float32), sk)
    small = LatentBank.from_state(bank.state(), capacity=3)
    assert len(small) == 3 and small.evictions == 3
    assert list(small._rows) == ["q3", "q4", "q5"]    # oldest dropped
    a, b = small.latents_at(small.row_of("q5"))
    ao, bo = bank.latents_at(bank.row_of("q5"))
    np.testing.assert_array_equal(a, ao)
    np.testing.assert_array_equal(b, bo)


# ---------------------------------------------------------------------------
# serving log + replay warm-up
# ---------------------------------------------------------------------------


def test_route_log_append_dedup_and_torn_tail(tmp_path):
    p = str(tmp_path / "routes.jsonl")
    with RouteLog(p) as log:
        log.append("alpha", model="m0", policy="balanced")
        log.append("beta", model="m1")
        log.append("alpha")                 # duplicate
        assert log.appended == 3
    with open(p, "a", encoding="utf-8") as f:
        f.write('{"text": "torn')           # crashed-server tail
    assert RouteLog.read_texts(p) == ["alpha", "beta"]
    assert RouteLog.read_texts(p, limit=1) == ["alpha"]
    assert RouteLog.read_texts(str(tmp_path / "missing.jsonl")) == []
    rec = json.loads(open(p, encoding="utf-8").readline())
    assert rec == {"text": "alpha", "model": "m0", "policy": "balanced"}


def test_warm_cache_fills_lru_without_skewing_stats(demo_stack):
    world, router, _ = demo_stack
    stream = _skewed_stream(world, seed=9, n=64)
    engine = _engine(router, "semantic")
    n = engine.warm_cache(stream + stream)   # dupes collapse
    assert n == len(set(stream))
    st = engine.cache_stats
    assert (st.hits, st.misses, st.semantic_hits) == (0, 0, 0), \
        "replay must not skew serving statistics"
    assert len(engine.cache._data) == n
    engine.route_batch(stream)
    assert engine.cache_stats.hit_rate == 1.0, \
        "warmed entries must serve the live stream"


def test_replay_log_through_router_open(demo_stack, tmp_path):
    """End to end: serve with a log, save, reopen with replay_log= — the
    reopened engine starts warm (first batch all hits)."""
    world, router, _ = demo_stack
    stream = _skewed_stream(world, seed=10, n=64)
    log_path = str(tmp_path / "routes.jsonl")
    with RouteLog(log_path) as log:
        for t in stream:
            log.append(t)
    art_dir = str(tmp_path / "art")
    router.save(art_dir)
    from repro.api import Router

    reopened = Router.open(art_dir, semantic_cache=True,
                           replay_log=log_path)
    assert reopened.calibration["replayed_texts"] == len(set(stream))
    eng = reopened.engine()
    eng.route_batch(stream)
    assert eng.cache_stats.hit_rate == 1.0
