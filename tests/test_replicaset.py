"""Supervised replica set (ISSUE 10): zero-divergence failover under
injected replica kills, the dispatch-time version fence against
partitioned admin fan-out, breaker state riding the fence across
failover, drain/rejoin warm resync, the heartbeat state machine, and
the resilient-client fixes (reconnect on a torn-down session, admin
replay idempotency)."""
import time

import numpy as np
import pytest

from repro.core.errors import (NoHealthyReplicaError, PoisonQueryError,
                               RetriesExhausted)
from repro.core.pool import BREAKER_CLOSED, BREAKER_OPEN
from repro.serving import (ReplicaSetConfig, ReplicaState, ReplicaSupervisor,
                           RouterEngine, RouterEngineConfig,
                           SemanticCacheConfig)
from repro.serving import faults
from repro.serving.faults import FaultEvent, FaultPlan
from repro.serving.protocol import BackgroundServer, ServiceClient
from repro.serving.service import RouterService


@pytest.fixture(autouse=True)
def _pristine_fault_state():
    faults.disarm()
    faults.reset_degraded()
    yield
    faults.disarm()
    faults.reset_degraded()


@pytest.fixture(scope="module")
def rstack(demo_stack):
    world, router, engine = demo_stack
    from repro.data import OOD_TASKS
    qi = world.query_indices(OOD_TASKS)
    texts = [world.queries[i].text for i in qi[:24]]
    return router, engine, texts


def _supervisor(router, n=3, **cfg_kw):
    return ReplicaSupervisor(
        router, n_replicas=n,
        engine_cfg=RouterEngineConfig(cache_size=256, **cfg_kw))


# ---------------------------------------------------------------------------
# failover: kill a replica mid-batch, selections stay bit-identical
# ---------------------------------------------------------------------------


def test_replica_kill_failover_is_bit_identical(rstack):
    router, engine, texts = rstack
    ref = engine.route_pinned(texts)            # single-engine reference
    sup = _supervisor(router, n=3)
    assert sup.healthy_count() == 3
    # hit 2 = the second shard dispatch → r1 dies mid-batch; its shard
    # fails over to the least-loaded survivor
    plan = FaultPlan([FaultEvent("replica.dispatch", "kill", (2,))])
    with faults.armed(plan):
        dec = sup.route_pinned(texts)
    assert plan.fired == [("replica.dispatch", "kill", 2)]
    # the acceptance bar: surviving selections bit-identical to the
    # single-engine run, and the failed set is exactly the killed
    # replica's unrecoverable residue — empty, because the re-dispatch
    # succeeded
    assert dec.names == ref.names
    assert np.array_equal(dec.sel, ref.sel)
    assert np.array_equal(dec.ranked, ref.ranked)
    assert dec.pool_version == ref.pool_version
    states = sup.replica_states()
    assert states["r1"] is ReplicaState.DEAD
    assert sup.healthy_count() == 2
    assert faults.degraded_counts().get("failover") == 1
    assert ("r1", "HEALTHY", "DEAD", "killed mid-batch (injected)") \
        in sup.transitions
    # rejoin brings it back warm; the set routes identically again
    sup.rejoin("r1")
    assert sup.healthy_count() == 3
    assert faults.degraded_counts().get("resync") == 1
    again = sup.route_pinned(texts)
    assert again.names == ref.names


def test_zero_queries_and_empty_rotation_edges(rstack):
    router, _, _ = rstack
    sup = _supervisor(router, n=2)
    dec = sup.route_pinned([])
    assert dec.names == [] and dec.sel.shape == (0,)
    assert dec.ranked.shape == (1, 0)
    for rep in list(sup.replicas):
        rep.killed = True
    t0 = time.monotonic()
    sup.tick(now=t0 + sup.cfg.suspect_after_s + 0.01)
    sup.tick(now=t0 + sup.cfg.dead_after_s + 0.01)
    assert all(r.state is ReplicaState.DEAD for r in sup.replicas)
    with pytest.raises(NoHealthyReplicaError, match="DEAD or DRAINING"):
        sup.route_pinned(["q"])


# ---------------------------------------------------------------------------
# the version fence: a partitioned replica never routes stale
# ---------------------------------------------------------------------------


def test_stale_fence_blocks_routes_against_old_pool_version(rstack):
    router, engine, texts = rstack
    sub = texts[:8]
    sup = _supervisor(router, n=2)
    name = router.pool.names[0]
    # outcome feedback bumps the pool version too — the fence covers it
    router.pool.record_outcome(name, True)
    v1 = router.pool.version
    # fan out under a partition: r0's push (hit 1) is dropped
    plan = FaultPlan([FaultEvent("replica.admin", "partition", (1,))])
    with faults.armed(plan):
        fan = sup.fanout()
    assert fan == {"pool_version": v1, "pushed": ["r1"]}
    assert sup.replicas[0].engine.adopted_version == v1 - 1
    assert sup.replicas[1].engine.adopted_version == v1
    # r0's shard trips the fence (typed StaleReplicaError), resyncs onto
    # the PINNED snapshot, and the retried shard merges — zero routes
    # ever answered against the old version
    dec = sup.route_pinned(sub)
    assert dec.pool_version == v1
    assert dec.names == engine.route_pinned(sub).names
    assert sup.replicas[0].engine.adopted_version == v1
    dc = faults.degraded_counts()
    assert dc.get("stale_fence") == 1
    assert dc.get("resync") == 1
    assert ("r0", "HEALTHY", "REJOINING", "stale fence") in sup.transitions
    assert ("r0", "REJOINING", "HEALTHY", "resynced") in sup.transitions


# ---------------------------------------------------------------------------
# breaker state rides the fence: open via report_outcome, then failover
# ---------------------------------------------------------------------------


def test_breaker_opened_before_kill_stays_masked_on_survivors(rstack):
    router, engine, texts = rstack
    sup = _supervisor(router, n=3)
    svc = RouterService(router, engine=sup)
    # break the model the reference selects most often
    base = engine.route_pinned(texts)
    name = max(set(base.names), key=base.names.count)
    snap = router.pool.snapshot()
    i = snap.index_of(name)
    pol = snap.health_policy
    try:
        for _ in range(pol.failure_threshold):
            info = svc.report_outcome(None, name, ok=False)
        assert info["state_after"] == "open"
        assert router.pool.snapshot().breaker[i] == BREAKER_OPEN
        # report_outcome fans the bumped snapshot out to every replica
        v = router.pool.version
        assert all(rep.engine.adopted_version == v
                   for rep in sup.replicas)
        ref = engine.route_pinned(texts)    # same pool state, one engine
        assert name not in ref.names
        plan = FaultPlan([FaultEvent("replica.dispatch", "kill", (2,))])
        with faults.armed(plan):
            dec = sup.route_pinned(texts)
        # the survivors absorbing the re-dispatched shard still mask the
        # broken model, bit-identically to the single-engine run
        assert dec.names == ref.names
        assert np.array_equal(dec.sel, ref.sel)
        assert name not in dec.names
        assert sup.healthy_count() == 2
    finally:
        t = time.time() + pol.open_cooldown_s + 1.0
        for _ in range(max(pol.half_open_probes, 1)):
            router.pool.record_outcome(name, True, now=t)
    assert router.pool.snapshot().breaker[i] == BREAKER_CLOSED


# ---------------------------------------------------------------------------
# poison quarantine through the replicated path: union of shard sets
# ---------------------------------------------------------------------------


def test_poison_union_across_shards(rstack):
    router, engine, texts = rstack
    sub = texts[:8]                 # shards: r0 ← 0..3, r1 ← 4..7
    sup = _supervisor(router, n=2)
    plan = FaultPlan([], poison_texts=[sub[1], sub[6]])
    with faults.armed(plan):
        with pytest.raises(PoisonQueryError) as ei:
            sup.route_pinned(sub)
    # exactly the union of the two shards' poison sets, batch-indexed
    assert list(ei.value.indices) == [1, 6]
    assert ei.value.texts == [sub[1], sub[6]]
    # poison is an input property, not a replica failure: rotation intact
    assert sup.healthy_count() == 2
    survivors = [t for j, t in enumerate(sub) if j not in (1, 6)]
    assert sup.route_pinned(survivors).names == \
        engine.route_pinned(survivors).names


# ---------------------------------------------------------------------------
# drain / rejoin: warm resync from a healthy peer
# ---------------------------------------------------------------------------


def test_drain_rejoin_resyncs_warm_state(rstack):
    router, engine, texts = rstack
    sup = ReplicaSupervisor(
        router, n_replicas=2,
        engine_cfg=RouterEngineConfig(
            cache_size=256, semantic_cache=SemanticCacheConfig()))
    sup.route_pinned(texts)         # both replicas warm their shards
    r0, r1 = sup.replicas
    sup.drain("r1")
    assert r1.state is ReplicaState.DRAINING
    d_before = r1.dispatches
    sup.route_pinned(texts[:8])     # drained replica gets no shards
    assert r1.dispatches == d_before
    # fan-out skips it too
    assert "r1" not in sup.fanout()["pushed"]
    # simulate a restart losing the warm state, then rejoin
    r1.engine.cache.clear()
    rep = sup.rejoin("r1")
    assert rep is r1 and r1.state is ReplicaState.HEALTHY
    assert len(r1.engine.cache._data) == len(r0.engine.cache._data) > 0
    assert set(r1.engine.cache._data) == set(r0.engine.cache._data)
    assert len(r1.engine.bank) == len(r0.engine.bank) > 0
    assert faults.degraded_counts().get("resync") == 1
    # rejoined warm with the PEER's entries: routing the peer-warmed
    # half of the corpus is pure cache-hit work on both replicas
    warmed = texts[:12]             # r0's shard from the first route
    h0 = sup.cache_stats.hits
    sup.route_pinned(warmed)
    assert sup.cache_stats.hits - h0 == len(warmed)


# ---------------------------------------------------------------------------
# heartbeats drive the state machine (injectable clock, no sleeping)
# ---------------------------------------------------------------------------


def test_heartbeat_suspect_dead_and_recovery(rstack):
    router, _, _ = rstack
    sup = ReplicaSupervisor(
        router, n_replicas=2,
        engine_cfg=RouterEngineConfig(cache_size=0),
        cfg=ReplicaSetConfig(suspect_after_s=0.5, dead_after_s=1.5))
    r0, r1 = sup.replicas
    t0 = time.monotonic()
    r0.killed = True
    sup.tick(now=t0 + 0.6)
    assert r0.state is ReplicaState.SUSPECT
    assert r1.state is ReplicaState.HEALTHY
    sup.tick(now=t0 + 2.5)
    assert r0.state is ReplicaState.DEAD
    # a DEAD replica only leaves through rejoin
    sup.rejoin("r0", now=t0 + 3.0)
    assert r0.state is ReplicaState.HEALTHY and not r0.killed
    # a beat resuming inside the suspect window walks SUSPECT → HEALTHY
    r1.killed = True
    sup.tick(now=t0 + 4.0)
    assert r1.state is ReplicaState.SUSPECT
    r1.killed = False
    sup.tick(now=t0 + 4.1)
    assert r1.state is ReplicaState.HEALTHY
    assert ("r1", "SUSPECT", "HEALTHY", "beat resumed") in sup.transitions


def test_slow_heartbeat_fault_misses_the_probe_window(rstack):
    router, _, _ = rstack
    sup = ReplicaSupervisor(
        router, n_replicas=1,
        engine_cfg=RouterEngineConfig(cache_size=0),
        cfg=ReplicaSetConfig(suspect_after_s=0.5, dead_after_s=5.0))
    (r0,) = sup.replicas
    t0 = time.monotonic()
    plan = FaultPlan([FaultEvent("replica.heartbeat", "slow", (1, 2))])
    with faults.armed(plan):
        sup.tick(now=t0 + 0.1)          # hit 1: beat arrives late
        assert r0.state is ReplicaState.HEALTHY     # window not yet blown
        sup.tick(now=t0 + 0.7)          # hit 2: still slow → SUSPECT
        assert r0.state is ReplicaState.SUSPECT
        sup.tick(now=t0 + 0.8)          # hit 3: beat resumes
        assert r0.state is ReplicaState.HEALTHY


def test_illegal_transition_is_a_bug_not_a_degradation(rstack):
    router, _, _ = rstack
    sup = ReplicaSupervisor(router, n_replicas=1,
                            engine_cfg=RouterEngineConfig(cache_size=0))
    with pytest.raises(RuntimeError, match="illegal replica transition"):
        sup._transition(sup.replicas[0], ReplicaState.STARTING, "test")


# ---------------------------------------------------------------------------
# service integration: gauges + stats expose replica state
# ---------------------------------------------------------------------------


def test_service_exports_replica_state_gauges(rstack):
    router, _, texts = rstack
    sup = _supervisor(router, n=2)
    svc = RouterService(router, engine=sup)
    st = svc.stats()
    assert st["replicas"] == {"r0": "healthy", "r1": "healthy"}
    plan = FaultPlan([FaultEvent("replica.dispatch", "kill", (1,))])
    with faults.armed(plan):
        sup.route_pinned(texts[:4])
    m = svc.render_metrics()
    assert 'router_replica_state{replica="r0"} 3' in m
    assert 'router_replica_state{replica="r1"} 1' in m
    assert 'router_degraded_total{path="failover"} 1' in m
    assert svc.stats()["replicas"]["r0"] == "dead"


# ---------------------------------------------------------------------------
# resilient client (satellite): every op rides the reconnect budget
# ---------------------------------------------------------------------------


def test_client_ops_reconnect_after_torn_down_session(rstack):
    router, engine, texts = rstack
    with BackgroundServer(router, engine=engine) as srv:
        with ServiceClient(srv.host, srv.port, retries=2,
                           backoff_s=0.01) as client:
            assert client.ping()["op"] == "pong"
            # a torn-down session (e.g. a prior exchange exhausted its
            # budget mid-reconnect) must re-establish on the NEXT op —
            # for every op type, not just route
            client._teardown()
            assert client.stats()["pool_version"] == router.pool.version
            client._teardown()
            assert "router_pool_version" in client.metrics()
            client._teardown()
            assert client.route(texts[0]).ok


def test_client_ops_raise_typed_retries_exhausted_when_down(rstack):
    router, engine, _ = rstack
    with BackgroundServer(router, engine=engine) as srv:
        host, port = srv.host, srv.port
        client = ServiceClient(host, port, retries=1, backoff_s=0.01,
                               timeout=2.0)
        assert client.ping()["op"] == "pong"
    # server gone: every op must exhaust the budget with the typed
    # error — including the SECOND call, which starts from a torn-down
    # session (the None-socket path)
    with pytest.raises(RetriesExhausted) as ei:
        client.stats()
    assert ei.value.attempts == 2
    with pytest.raises(RetriesExhausted):
        client.metrics()
    with pytest.raises(RetriesExhausted):
        client.report_outcome(None, router.pool.names[0], ok=True)
    client.close()


def test_admin_replay_answers_from_dedup_cache(rstack):
    router, engine, _ = rstack
    name = router.pool.names[0]
    orig = float(router.pool.snapshot().lam_in[
        router.pool.snapshot().index_of(name), 0])
    with BackgroundServer(router, engine=engine) as srv:
        with ServiceClient(srv.host, srv.port, retries=3,
                           backoff_s=0.01) as client:
            v0 = router.pool.version
            # the admin frame is handled, then the connection resets
            # before the reply flushes; the client replays the SAME
            # idempotency key and must be answered from the dedup cache
            # — the mutation runs ONCE (one version bump, not two)
            plan = FaultPlan([
                FaultEvent("protocol.frame", "reset_post", (1,))])
            try:
                with faults.armed(plan):
                    info = client.admin.update_pricing(
                        name, price_in=orig * 2.0)
                assert plan.fired == \
                    [("protocol.frame", "reset_post", 1)]
                assert info["pool_version"] == v0 + 1
                assert router.pool.version == v0 + 1
            finally:
                client.admin.update_pricing(name, price_in=orig)
