"""RouterEngine serving layer: cache semantics, padded-bucket bitwise
equivalence, reference-path agreement, scheduler ordering (ISSUE 1);
snapshot consumption over the versioned ModelPool (ISSUE 2)."""
import dataclasses

import numpy as np
import pytest

from repro.core.router import POLICIES
from repro.data import ID_TASKS
from repro.data.tokenizer import HashTokenizer, piece_count
from repro.serving import (LatentCache, MicroBatcher, RouterEngine,
                           RouterEngineConfig)


@pytest.fixture(scope="module")
def served(demo_stack):
    world, router, engine = demo_stack
    from repro.data import OOD_TASKS
    qi = world.query_indices(OOD_TASKS)
    texts = [world.queries[i].text for i in qi[:48]]
    return world, router, engine, texts


# ---------------------------------------------------------------------------
# scoring equivalence
# ---------------------------------------------------------------------------


def test_engine_matches_seed_score_queries(served):
    """Vectorized batched scoring vs the eager reference path: the
    table/cost/latency stages are bit-for-bit (same f64 numpy ops); the
    jitted predictor forward matches the eager one to f32 resolution."""
    _, router, _, texts = served
    engine = RouterEngine(router, RouterEngineConfig(cache_size=0))
    p_e, c_e, l_e = engine.score_queries(texts)
    p_s, c_s, l_s = router.score(texts)
    np.testing.assert_allclose(p_e, p_s, atol=2e-6)
    np.testing.assert_array_equal(c_e, c_s)
    np.testing.assert_array_equal(l_e, l_s)


def test_padded_bucket_scoring_is_bitwise_invariant(served):
    """Padding to a bucket must be invisible: scoring a 13-query batch
    (padded to 16) equals the same queries scored inside a 48-query batch
    bit-for-bit on the unpadded entries."""
    _, router, _, texts = served
    engine = RouterEngine(router, RouterEngineConfig(cache_size=0))
    p_full, c_full, l_full = engine.score_queries(texts)
    p_sub, c_sub, l_sub = engine.score_queries(texts[:13])
    np.testing.assert_array_equal(p_sub, p_full[:, :13])
    np.testing.assert_array_equal(c_sub, c_full[:, :13])
    np.testing.assert_array_equal(l_sub, l_full[:, :13])


def test_cache_hits_are_bitwise_identical(served):
    """Cold scoring vs fully-cached scoring of the same batch."""
    _, router, _, texts = served
    engine = RouterEngine(router, RouterEngineConfig(cache_size=256))
    cold = engine.score_queries(texts)
    assert engine.cache_stats.misses > 0 and engine.cache_stats.hits == 0
    warm = engine.score_queries(texts)
    assert engine.cache_stats.hits == len(texts)
    for a, b in zip(cold, warm):
        np.testing.assert_array_equal(a, b)


def test_selections_identical_to_reference_router(served):
    _, router, _, texts = served
    engine = RouterEngine(router, RouterEngineConfig(cache_size=256))
    for pol in POLICIES:
        _, sel_seed, _ = router.route(texts, policy=pol)
        _, sel_eng, _ = engine.route(texts, policy=pol)
        _, sel_fast = engine.route_batch(texts, policy=pol)
        np.testing.assert_array_equal(np.asarray(sel_seed), sel_eng)
        np.testing.assert_array_equal(np.asarray(sel_seed), sel_fast)


def test_chunking_over_max_batch(served):
    """Q > max_batch is chunked internally and reassembled in order."""
    _, router, _, texts = served
    small = RouterEngine(router, RouterEngineConfig(cache_size=0, max_batch=16))
    big = RouterEngine(router, RouterEngineConfig(cache_size=0))
    for a, b in zip(small.score_queries(texts), big.score_queries(texts)):
        np.testing.assert_array_equal(a, b)
    # routing over max_batch keeps GLOBAL cost normalization: selections
    # must match the un-chunked route() on the full batch
    _, sel_ref, _ = small.route(texts)
    _, sel_fast = small.route_batch(texts)
    np.testing.assert_array_equal(np.asarray(sel_ref), sel_fast)


# ---------------------------------------------------------------------------
# cache semantics
# ---------------------------------------------------------------------------


def test_lru_eviction_order():
    cache = LatentCache(maxsize=2)
    from repro.serving.cache import CacheEntry
    e = lambda: CacheEntry(np.zeros(2), np.zeros(2), np.zeros(2), {})
    cache.put("a", e())
    cache.put("b", e())
    assert cache.get("a") is not None      # a is now most-recent
    cache.put("c", e())                    # evicts b
    assert "b" not in cache and "a" in cache and "c" in cache
    assert cache.stats.evictions == 1
    assert cache.get("b") is None
    assert cache.stats.misses == 1


def test_lru_eviction_at_capacity_boundary():
    """Exactly-at-capacity inserts must not evict; the (cap+1)-th insert
    evicts exactly the least-recently-USED entry; re-putting an existing
    key refreshes recency without changing size."""
    from repro.serving.cache import CacheEntry
    cap = 4
    cache = LatentCache(maxsize=cap)
    e = lambda: CacheEntry(np.zeros(2), np.zeros(2), np.zeros(2), {})
    for i in range(cap):
        cache.put(f"t{i}", e())
    assert len(cache) == cap and cache.stats.evictions == 0
    # re-put an existing key at capacity: refresh, not insert
    cache.put("t0", e())
    assert len(cache) == cap and cache.stats.evictions == 0
    # t1 is now LRU (t0 was refreshed); the boundary-crossing insert
    # evicts exactly it
    cache.put("new", e())
    assert len(cache) == cap and cache.stats.evictions == 1
    assert "t1" not in cache
    assert all(k in cache for k in ("t0", "t2", "t3", "new"))


def test_pool_mutation_keeps_cache_and_rebuilds_snapshot(served):
    """onboard/remove only bump pool_version: the latent cache survives
    (latents are pool-independent) while scoring reflects the new pool."""
    world, router, _, texts = served
    engine = RouterEngine(router, RouterEngineConfig(cache_size=256))
    engine.score_queries(texts)
    n_cached = len(engine.cache)
    v0 = router.pool.version
    m = world.model_index("future-model-00")
    anchors = world.query_indices(ID_TASKS)[router.artifacts.anchor_idx]
    y = world.sample_responses([m], anchors)[0]
    lens = world.output_lengths([m], anchors)[0]
    lats = world.true_latency([m], anchors, lens[None])[0]
    mi = world.models[m]
    router.onboard("future-model-00", y, lens, lats, mi.price_in,
                   mi.price_out, mi.tokenizer)
    assert router.pool.version == v0 + 1
    try:
        p_e, c_e, l_e = engine.score_queries(texts)
        assert len(engine.cache) == n_cached, "pool mutation purged cache"
        assert p_e.shape[0] == len(router.pool)
        p_s, c_s, l_s = router.score(texts)
        np.testing.assert_allclose(p_e, p_s, atol=2e-6)
        np.testing.assert_array_equal(c_e, c_s)
        np.testing.assert_array_equal(l_e, l_s)
    finally:
        router.remove("future-model-00")
    assert engine.score_queries(texts)[0].shape[0] == len(router.pool)


def test_predictor_swap_clears_cache(served):
    """Swapping the predictor produces a NEW (frozen) artifacts object;
    the engine detects the identity change and clears its latent cache."""
    _, router, _, texts = served
    engine = RouterEngine(router, RouterEngineConfig(cache_size=256))
    engine.score_queries(texts)
    assert len(engine.cache) > 0
    old_art, old_pred = router.artifacts, router.predictor
    try:
        router.set_predictor(dataclasses.replace(old_pred))  # identity swap
        assert router.artifacts is not old_art
        engine.score_queries(texts[:4])
        assert engine.cache_stats.hits == 0         # cache was cleared
        assert len(engine.cache) == 4
    finally:
        router.artifacts = old_art


# ---------------------------------------------------------------------------
# micro-batcher
# ---------------------------------------------------------------------------


def test_batcher_coalesces_and_preserves_order(served):
    _, router, engine, texts = served
    # flush() drains FIFO into batches of exactly max_batch, each routed
    # independently (per-batch cost normalization — serving semantics)
    names_ref = []
    for i in range(0, len(texts), 8):
        names_ref.extend(engine.route_batch(texts[i: i + 8])[0])
    mb = MicroBatcher(engine, max_batch=8)
    futs = mb.submit_many(texts)
    routed = mb.flush()
    assert routed == len(texts)
    assert mb.batches_routed >= len(texts) // 8
    results = [f.result(timeout=5) for f in futs]
    assert [r.model for r in results] == names_ref
    assert [r.text for r in results] == list(texts)


def test_batcher_survives_cancelled_future(served):
    """A caller cancelling its pending future must not poison the batch
    or kill the scheduler."""
    _, router, engine, texts = served
    mb = MicroBatcher(engine, max_batch=8)
    futs = mb.submit_many(texts[:8])
    assert futs[3].cancel()
    mb.flush()
    done = [f.result(timeout=5) for i, f in enumerate(futs) if i != 3]
    assert len(done) == 7 and all(r.model for r in done)
    # scheduler still alive for the next batch
    fut = mb.submit(texts[0])
    mb.flush()
    assert fut.result(timeout=5).model


def test_batcher_mixed_policies(served):
    _, router, engine, texts = served
    mb = MicroBatcher(engine, max_batch=64)
    futs = ([mb.submit(t, policy="min_cost") for t in texts[:8]]
            + [mb.submit(t, policy="max_acc") for t in texts[:8]])
    mb.flush()
    res = [f.result(timeout=5) for f in futs]
    _, sel_cost = engine.route_batch(texts[:8], policy="min_cost")
    _, sel_acc = engine.route_batch(texts[:8], policy="max_acc")
    assert [r.model_index for r in res[:8]] == list(sel_cost)
    assert [r.model_index for r in res[8:]] == list(sel_acc)


def test_batcher_threaded_mode(served):
    _, router, engine, texts = served
    names_ref, _, _ = engine.route(texts[:16])
    with MicroBatcher(engine, max_batch=8, max_wait_s=0.01) as mb:
        futs = [mb.submit(t) for t in texts[:16]]
        results = [f.result(timeout=30) for f in futs]
    assert [r.model for r in results] == list(names_ref)


def test_batcher_fan_back_under_concurrent_producers(served):
    """Out-of-order completion: many producer threads submit interleaved
    requests with jittered timing; every future must resolve with the
    decision for ITS OWN text (the fan-back may not cross wires), no
    matter how submissions interleave into batches."""
    import threading
    import time as _time

    _, router, engine, texts = served
    n_producers, per_producer = 6, 12
    results = [[None] * per_producer for _ in range(n_producers)]
    errors = []

    with MicroBatcher(engine, max_batch=16, max_wait_s=0.002) as mb:
        def produce(k):
            try:
                rng = np.random.default_rng(k)
                futs = []
                for j in range(per_producer):
                    # unique text per (producer, slot) so a crossed wire
                    # is detectable
                    futs.append((j, mb.submit(
                        f"{texts[(k * per_producer + j) % len(texts)]} "
                        f"[p{k}q{j}]")))
                    if rng.random() < 0.5:
                        _time.sleep(rng.random() * 0.003)
                for j, f in futs:
                    results[k][j] = f.result(timeout=30)
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=produce, args=(k,))
                   for k in range(n_producers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
    assert not errors
    for k in range(n_producers):
        for j in range(per_producer):
            r = results[k][j]
            assert r is not None
            assert r.text.endswith(f"[p{k}q{j}]"), "fan-back crossed wires"
            assert r.model == router.pool.names[r.model_index]
    assert mb.requests_routed == n_producers * per_producer


def test_batcher_max_wait_expiry_routes_partial_batch(served):
    """A partially-filled batch must be routed once max_wait expires —
    without further submissions or a flush()."""
    _, _, engine, texts = served
    with MicroBatcher(engine, max_batch=64, max_wait_s=0.01) as mb:
        futs = [mb.submit(t) for t in texts[:3]]
        results = [f.result(timeout=30) for f in futs]
    assert [r.text for r in results] == list(texts[:3])
    assert all(r.model for r in results)
    assert mb.batches_routed == 1, "partial batch was not coalesced once"
    assert mb.requests_routed == 3


# ---------------------------------------------------------------------------
# vectorized input lengths
# ---------------------------------------------------------------------------


def test_piece_count_matches_tokenizer():
    texts = ["", "hello", "a much longer query with punctuation?! and 123",
             "antidisestablishmentarianism " * 3]
    for sw in (4, 12, 30):
        tok = HashTokenizer(1000, salt="x", subword_len=sw)
        for t in texts:
            assert piece_count(t, sw) == tok.count(t)


def test_input_lengths_match_per_model_loop(served):
    """The engine's one-pass ℓ_in equals the seed's M × Q tokenizer loop
    exactly, including length factors."""
    from repro.data.tokenizer import model_token_count
    _, router, _, texts = served
    engine = RouterEngine(router, RouterEngineConfig(cache_size=0))
    pool = engine._pool()
    _, _, entries = engine._latent_batch(texts, pool)
    l_in = engine._input_lengths(texts, entries, pool)
    want = np.array([[model_token_count(tok, t) for t in texts]
                     for tok in router.pool.snapshot().tokenizers])
    np.testing.assert_array_equal(l_in, want)
