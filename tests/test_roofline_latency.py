"""Roofline-grounded latency estimation (beyond-paper, DESIGN.md §2):
TTFT/TPOT for the router derived from compiled dry-run artifacts."""
import os

import pytest

from repro.core.latency import RooflineLatencyModel

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                          "experiments", "dryrun")


@pytest.fixture(scope="module")
def model():
    m = RooflineLatencyModel(DRYRUN_DIR)
    if not m.records:
        pytest.skip("no dry-run artifacts (run repro.launch.dryrun first)")
    return m


def test_params_positive_and_finite(model):
    for arch in ("gemma3-1b", "llama3-405b", "qwen2-72b"):
        if not model.available(arch):
            pytest.skip(f"{arch} artifacts missing")
        ttft, tpot = model.params_for(arch, prompt_len=512)
        assert 0 < ttft < 60, (arch, ttft)
        assert 0 < tpot < 60, (arch, tpot)


def test_bigger_models_are_slower(model):
    """The estimator must preserve the serving-cost ordering the router
    relies on: a 405B dense model decodes slower than a 1B one."""
    if not (model.available("gemma3-1b") and model.available("llama3-405b")):
        pytest.skip("artifacts missing")
    _, tpot_small = model.params_for("gemma3-1b")
    _, tpot_big = model.params_for("llama3-405b")
    assert tpot_big > tpot_small


def test_ttft_scales_with_prompt(model):
    if not model.available("gemma3-1b"):
        pytest.skip("artifacts missing")
    t_short, _ = model.params_for("gemma3-1b", prompt_len=128)
    t_long, _ = model.params_for("gemma3-1b", prompt_len=8192)
    assert t_long > t_short


def test_latency_params_batch(model):
    archs = [a for a in ("gemma3-1b", "qwen2-72b") if model.available(a)]
    if not archs:
        pytest.skip("artifacts missing")
    lp = model.latency_params(archs)
    pred = lp.predict(__import__("numpy").full((len(archs), 3), 100.0))
    assert pred.shape == (len(archs), 3)
    assert (pred > 0).all()
