"""Model-substrate numerics: blocked flash attention (fwd + custom VJP),
sliding window, mLSTM chunked-vs-recurrent, mamba seq-vs-step, MoE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import decode_attention, flash_attention, sliding_attention
from repro.sharding.planner import NULL_CTX


def _naive(q, k, v, q_pos, kv_pos, window=0, scale=None):
    B, L, nq, dk = q.shape
    S, nkv = k.shape[1], k.shape[2]
    G = nq // nkv
    scale = dk ** -0.5 if scale is None else scale
    qg = jnp.moveaxis(q.reshape(B, L, nkv, G, dk), 1, 3).astype(jnp.float32)
    kg = jnp.moveaxis(k, 1, 2).astype(jnp.float32)
    vg = jnp.moveaxis(v, 1, 2).astype(jnp.float32)
    s = jnp.einsum("bkgld,bksd->bkgls", qg, kg) * scale
    ok = (kv_pos[:, None, None, None, :] >= 0) & (
        q_pos[:, None, None, :, None] >= kv_pos[:, None, None, None, :])
    if window:
        ok &= q_pos[:, None, None, :, None] - kv_pos[:, None, None, None, :] < window
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgls,bksd->bkgld", p, vg)
    return jnp.moveaxis(o, 3, 1).reshape(B, L, nq, -1).astype(q.dtype)


@pytest.mark.parametrize("B,L,nq,nkv,dk", [(2, 64, 4, 2, 32), (1, 128, 8, 1, 16)])
def test_flash_matches_naive(B, L, nq, nkv, dk):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, L, nq, dk))
    k = jax.random.normal(ks[1], (B, L, nkv, dk))
    v = jax.random.normal(ks[2], (B, L, nkv, dk))
    pos = jnp.broadcast_to(jnp.arange(L), (B, L))
    out = flash_attention(q, k, v, pos, pos, block_q=16, block_kv=32)
    want = _naive(q, k, v, pos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_flash_custom_vjp_matches_autodiff():
    """The hand-written flash backward == autodiff through naive attention."""
    ks = jax.random.split(jax.random.key(1), 3)
    B, L, nq, nkv, dk = 1, 32, 4, 2, 16
    q = jax.random.normal(ks[0], (B, L, nq, dk))
    k = jax.random.normal(ks[1], (B, L, nkv, dk))
    v = jax.random.normal(ks[2], (B, L, nkv, dk))
    pos = jnp.broadcast_to(jnp.arange(L), (B, L))

    def f_flash(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(q, k, v, pos, pos,
                                               block_q=8, block_kv=8)))

    def f_naive(q, k, v):
        return jnp.sum(jnp.sin(_naive(q, k, v, pos, pos)))

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b, n in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4,
                                   err_msg=f"d{n}")


def test_sliding_matches_naive_windowed():
    ks = jax.random.split(jax.random.key(2), 3)
    B, L, nq, nkv, dk, W = 2, 128, 4, 2, 16, 32
    q = jax.random.normal(ks[0], (B, L, nq, dk))
    k = jax.random.normal(ks[1], (B, L, nkv, dk))
    v = jax.random.normal(ks[2], (B, L, nkv, dk))
    pos = jnp.broadcast_to(jnp.arange(L), (B, L))
    out = sliding_attention(q, k, v, pos, pos, window=W, block_q=16)
    want = _naive(q, k, v, pos, pos, window=W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_decode_matches_naive_last_row():
    ks = jax.random.split(jax.random.key(3), 3)
    B, S, nq, nkv, dk = 2, 64, 4, 2, 16
    q = jax.random.normal(ks[0], (B, 1, nq, dk))
    kc = jax.random.normal(ks[1], (B, S, nkv, dk))
    vc = jax.random.normal(ks[2], (B, S, nkv, dk))
    kv_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    cur = jnp.array([S - 1, S // 2])
    out = decode_attention(q, kc, vc, kv_pos, cur)
    want = _naive(q, kc, vc, cur[:, None], kv_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


# ---------------------------------------------------------------------------
# SSM blocks
# ---------------------------------------------------------------------------


def _xlstm_cfg():
    from repro.configs import get_smoke_config
    return get_smoke_config("xlstm-125m")


def test_mlstm_chunked_equals_stepwise():
    from repro.models.ssm import init_mlstm_params, init_mlstm_state, mlstm_seq, mlstm_step
    cfg = _xlstm_cfg()
    p = init_mlstm_params(jax.random.key(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.key(1), (2, 12, cfg.d_model))
    out_seq, st_seq = mlstm_seq(p, x, cfg, chunk=4)
    st = init_mlstm_state(cfg, 2)
    outs = []
    for t in range(12):
        o, st = mlstm_step(p, x[:, t:t + 1], cfg, st)
        outs.append(o)
    out_step = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(out_seq), np.asarray(out_step),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_seq["C"]), np.asarray(st["C"]),
                               atol=2e-4)


def test_mamba_seq_equals_stepwise():
    from repro.configs import get_smoke_config
    from repro.models.ssm import init_mamba_params, init_mamba_state, mamba_seq, mamba_step
    cfg = get_smoke_config("hymba-1.5b")
    p = init_mamba_params(jax.random.key(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.key(1), (2, 10, cfg.d_model))
    out_seq, st_seq = mamba_seq(p, x, cfg, chunk=5)
    st = init_mamba_state(cfg, 2, jnp.float32)
    outs = []
    for t in range(10):
        o, st = mamba_step(p, x[:, t:t + 1], cfg, st)
        outs.append(o)
    out_step = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(out_seq), np.asarray(out_step),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_seq["ssm"]), np.asarray(st["ssm"]),
                               atol=2e-4)


def test_slstm_scan_shapes_and_state():
    from repro.models.ssm import init_slstm_params, slstm_seq
    cfg = _xlstm_cfg()
    p = init_slstm_params(jax.random.key(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
    out, st = slstm_seq(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert bool(jnp.all(st["n"] >= 0))


# ---------------------------------------------------------------------------
# MoE dispatch
# ---------------------------------------------------------------------------


def test_moe_single_expert_equals_dense():
    """E=1, k=1: the MoE must reduce to its single expert's SwiGLU."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models.moe import init_moe_params, moe_ffn

    base = get_smoke_config("kimi-k2-1t-a32b")
    mo = dataclasses.replace(base.moe, num_experts=1, num_experts_per_tok=1,
                             num_shared_experts=0, capacity_factor=4.0)
    cfg = dataclasses.replace(base, moe=mo)
    p = init_moe_params(jax.random.key(0), cfg, jnp.float32)
    x = 0.1 * jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
    out, aux = moe_ffn(p, x, cfg, NULL_CTX)
    from repro.models.layers import swiglu
    want = swiglu(x, p["w_gate"][0], p["w_up"][0], p["w_down"][0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_moe_grads_flow_to_router():
    from repro.configs import get_smoke_config
    from repro.models.moe import init_moe_params, moe_ffn
    cfg = get_smoke_config("kimi-k2-1t-a32b")
    p = init_moe_params(jax.random.key(0), cfg, jnp.float32)
    x = 0.1 * jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))

    def loss(p):
        out, aux = moe_ffn(p, x, cfg, NULL_CTX)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0, "router must receive grads"
    assert float(jnp.abs(g["w_gate"]).sum()) > 0


def test_moe_dropless_decode_never_drops():
    """Serving fix (DESIGN §10): decode dispatch is dropless — with a
    capacity factor that WOULD drop tokens in train mode, every token's
    expert output must be present (nonzero) in dropless mode."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models.moe import init_moe_params, moe_ffn
    base = get_smoke_config("kimi-k2-1t-a32b")
    # pathological capacity: train-mode capacity = ceil(T*k/E*0.25) drops most
    mo = dataclasses.replace(base.moe, capacity_factor=0.25,
                             num_shared_experts=0)
    cfg = dataclasses.replace(base, moe=mo)
    p = init_moe_params(jax.random.key(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.key(1), (4, 4, cfg.d_model))
    out_train, _ = moe_ffn(p, x, cfg, NULL_CTX, dropless=False)
    out_serve, _ = moe_ffn(p, x, cfg, NULL_CTX, dropless=True)
    dropped_train = jnp.mean(jnp.all(out_train == 0, axis=-1))
    dropped_serve = jnp.mean(jnp.all(out_serve == 0, axis=-1))
    assert float(dropped_train) > 0.2, "capacity 0.25 should drop tokens"
    assert float(dropped_serve) == 0.0, "dropless decode must not drop"
