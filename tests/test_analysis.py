"""Tier-1 tests for the routerlint static-analysis pass (repro.analysis).

Three layers:

* **fixture tests** — every rule is exercised against a committed bad
  snippet under ``tests/fixtures/analysis/bad/`` (the rule must fire)
  and a good twin under ``good/`` (it must stay silent).  Fixtures are
  copied into a scratch repo tree at the path the rule scopes to, so
  the checkers see exactly what they would see in the live repo.
* **framework tests** — suppression comments, the baseline lifecycle
  (add -> grandfather -> fix -> stale-entry error), the JSON report's
  stable schema, and the CLI's exit codes.
* **self-check** — the live repo is clean modulo its committed
  baseline, which doubles as the regression lock for the wall-clock and
  parity-gap findings fixed in this PR.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (CHECKERS, all_rules, load_baseline,
                            load_repo, run_analysis, write_baseline)
from repro.analysis.__main__ import main as lint_main
from repro.analysis.report import JSON_REPORT_VERSION, report_to_json

REPO_ROOT = Path(__file__).resolve().parents[1]
FIXTURES = REPO_ROOT / "tests" / "fixtures" / "analysis"


def fixture(rel: str) -> str:
    return (FIXTURES / rel).read_text()


def make_repo(tmp_path: Path, files: dict):
    """Materialize {repo-relative path: source text} and load it."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src)
    return load_repo(tmp_path)


def rules_fired(report):
    return sorted({f.rule for f in report.findings})


# ----------------------------------------------------------------------
# jit-purity
# ----------------------------------------------------------------------
def test_jit_purity_bad_fixture_fires_branch_and_host_rules(tmp_path):
    repo = make_repo(tmp_path, {
        "src/repro/core/scoring.py": fixture("bad/jit_branch_host.py")})
    report = run_analysis(repo, only=["jit-purity"])
    by_rule = {}
    for f in report.findings:
        by_rule.setdefault(f.rule, []).append(f)
    # clamp's `if`, top_scores' `while`
    assert len(by_rule["jit-branch-on-traced"]) == 2
    # np.sort + print
    assert len(by_rule["jit-host-call"]) == 2
    msgs = " ".join(f.message for f in by_rule["jit-host-call"])
    assert "np.sort" in msgs and "print" in msgs


def test_deleting_pr4_params_as_arguments_pattern_is_caught(tmp_path):
    """The acceptance criterion: a fixture copy of serving/engine.py
    with the params-as-jit-arguments pattern deleted (weights read from
    ``pred.params`` closure state) trips jit-closure-params."""
    repo = make_repo(tmp_path, {
        "src/repro/serving/engine.py": fixture("bad/engine_closure.py")})
    report = run_analysis(repo, only=["jit-purity"])
    closure = [f for f in report.findings
               if f.rule == "jit-closure-params"]
    assert len(closure) == 2          # enc + heads reads of pred.params
    assert all(f.path == "src/repro/serving/engine.py" for f in closure)
    assert all("pred.params" in f.message for f in closure)
    assert all(f.symbol.endswith("_build_jits._latents")
               for f in closure)


def test_jit_purity_good_fixture_is_clean(tmp_path):
    """Params-as-arguments plus static_argnames/static_argnums branches
    must NOT fire — the live ops.py dispatchers rely on this."""
    repo = make_repo(tmp_path, {
        "src/repro/core/scoring.py": fixture("good/jit_clean.py")})
    assert run_analysis(repo, only=["jit-purity"]).clean


# ----------------------------------------------------------------------
# kernel-contract
# ----------------------------------------------------------------------
def test_kernel_without_ref_twin_is_flagged(tmp_path):
    repo = make_repo(tmp_path, {
        "src/repro/kernels/fancy_scan.py": fixture("bad/kernel_orphan.py"),
        "src/repro/kernels/ref.py": "def other_ref(x):\n    return x\n"})
    report = run_analysis(repo, only=["kernel-contract"])
    assert rules_fired(report) == ["kernel-missing-ref"]
    assert "fancy_scan" in report.findings[0].message


def test_kernel_with_ref_but_no_parity_test_is_flagged(tmp_path):
    repo = make_repo(tmp_path, {
        "src/repro/kernels/fancy_scan.py": fixture("bad/kernel_orphan.py"),
        "src/repro/kernels/ref.py": fixture("good/kernel_ref_twin.py"),
        "tests/test_kernels.py": "def test_unrelated():\n    pass\n"})
    report = run_analysis(repo, only=["kernel-contract"])
    assert rules_fired(report) == ["kernel-missing-parity-test"]
    assert "fancy_scan_ref" in report.findings[0].message


def test_kernel_with_ref_and_parity_test_is_clean(tmp_path):
    test_src = ("from repro.kernels import ref\n"
                "from repro.kernels.fancy_scan import fancy_scan_tpu\n"
                "def test_parity():\n"
                "    assert fancy_scan_tpu is not ref.fancy_scan_ref\n")
    repo = make_repo(tmp_path, {
        "src/repro/kernels/fancy_scan.py": fixture("bad/kernel_orphan.py"),
        "src/repro/kernels/ref.py": fixture("good/kernel_ref_twin.py"),
        "tests/test_kernels.py": test_src})
    assert run_analysis(repo, only=["kernel-contract"]).clean


def test_ref_mention_inside_ref_name_does_not_count_as_kernel_side(
        tmp_path):
    """`fancy_scan` inside `fancy_scan_ref` must not satisfy the
    kernel-entry-point requirement (word-boundary matching)."""
    test_src = ("from repro.kernels.ref import fancy_scan_ref\n"
                "def test_half():\n"
                "    fancy_scan_ref(None)\n")
    repo = make_repo(tmp_path, {
        "src/repro/kernels/fancy_scan.py": fixture("bad/kernel_orphan.py"),
        "src/repro/kernels/ref.py": fixture("good/kernel_ref_twin.py"),
        "tests/test_kernels.py": test_src})
    report = run_analysis(repo, only=["kernel-contract"])
    assert rules_fired(report) == ["kernel-missing-parity-test"]
    assert "entry point" in report.findings[0].message


def test_dynamic_blockspec_shape_elements_are_flagged(tmp_path):
    repo = make_repo(tmp_path, {
        "src/repro/kernels/halved.py":
            fixture("bad/kernel_dynamic_blockspec.py"),
        "src/repro/kernels/ref.py": "def halved_ref(x):\n    return x\n"})
    report = run_analysis(repo, only=["kernel-contract"])
    dynamic = [f for f in report.findings
               if f.rule == "kernel-blockspec-dynamic"]
    # rows * 0.5 (float) and pick_tile(x) (non-whitelisted call)
    assert len(dynamic) == 2


# ----------------------------------------------------------------------
# async-safety
# ----------------------------------------------------------------------
def test_async_safety_bad_fixture_fires_all_three_rules(tmp_path):
    repo = make_repo(tmp_path, {
        "src/repro/serving/handlers.py": fixture("bad/async_service.py")})
    report = run_analysis(repo, only=["async-safety"])
    by_rule = {}
    for f in report.findings:
        by_rule.setdefault(f.rule, []).append(f)
    assert len(by_rule["async-global-state"]) == 1
    assert len(by_rule["monotonic-time"]) == 2
    # time.sleep, open, create_connection, sendall, subprocess.run,
    # ServiceClient — and NOT the time.sleep in the nested sync def
    assert len(by_rule["async-blocking-call"]) == 6
    names = " ".join(f.message for f in by_rule["async-blocking-call"])
    for expected in ("time.sleep", "open", "socket.create_connection",
                     "peer.sendall", "subprocess.run", "ServiceClient"):
        assert expected in names


def test_async_safety_good_fixture_is_clean(tmp_path):
    repo = make_repo(tmp_path, {
        "src/repro/serving/handlers.py": fixture("good/async_service.py")})
    assert run_analysis(repo, only=["async-safety"]).clean


def test_async_safety_ignores_out_of_scope_modules(tmp_path):
    """core/ may use time.time() for persisted wall-clock timestamps
    (pool.py breaker opened_at) — the rule scopes to serving/+launch/."""
    repo = make_repo(tmp_path, {
        "src/repro/core/pool.py":
            "import time\n\ndef stamp():\n    return time.time()\n"})
    assert run_analysis(repo, only=["async-safety"]).clean


# ----------------------------------------------------------------------
# schema-migration
# ----------------------------------------------------------------------
def test_schema_bump_without_migration_step_is_flagged(tmp_path):
    repo = make_repo(tmp_path, {
        "src/repro/core/store.py": fixture("bad/schema_drift.py")})
    report = run_analysis(repo, only=["schema-migration"])
    assert rules_fired(report) == ["schema-migration-chain"]
    assert "[2]" in report.findings[0].message


def test_schema_version_literals_outside_schema_modules_are_flagged(
        tmp_path):
    repo = make_repo(tmp_path, {
        "src/repro/serving/export.py": fixture("bad/schema_literal.py")})
    report = run_analysis(repo, only=["schema-migration"])
    # dict literal, subscript store, keyword arg
    assert [f.rule for f in report.findings] == \
        ["schema-version-literal"] * 3


def test_full_migration_chain_is_clean(tmp_path):
    repo = make_repo(tmp_path, {
        "src/repro/core/store.py": fixture("good/schema_chain.py")})
    assert run_analysis(repo, only=["schema-migration"]).clean


def test_register_artifact_migration_decorator_covers_a_version(tmp_path):
    src = ("CKPT_SCHEMA_VERSION = 2\n\n"
           "@register_artifact_migration(1)\n"
           "def _v1(rec):\n    return rec\n")
    repo = make_repo(tmp_path, {"src/repro/checkpoint/ckpt.py": src})
    assert run_analysis(repo, only=["schema-migration"]).clean


# ----------------------------------------------------------------------
# precision-hygiene
# ----------------------------------------------------------------------
def test_low_precision_dtypes_in_scoring_stack_are_flagged(tmp_path):
    repo = make_repo(tmp_path, {
        "src/repro/core/rescore.py": fixture("bad/precision_leak.py")})
    report = run_analysis(repo, only=["precision-hygiene"])
    # jnp.bfloat16, "float16", dtype="bfloat16", np.float16
    assert [f.rule for f in report.findings] == ["precision-dtype"] * 4


def test_precision_rule_ignores_f32_and_out_of_scope_trees(tmp_path):
    repo = make_repo(tmp_path, {
        "src/repro/core/rescore.py": fixture("good/precision_f32.py"),
        # checkpoint/ hosts the bf16 codec on purpose — out of scope
        "src/repro/checkpoint/codec.py":
            "import jax.numpy as jnp\n\n"
            "def pack(x):\n    return x.astype(jnp.bfloat16)\n"})
    assert run_analysis(repo, only=["precision-hygiene"]).clean


# ----------------------------------------------------------------------
# degradation-hygiene
# ----------------------------------------------------------------------
def test_degradation_bad_fixture_fires_both_rules(tmp_path):
    repo = make_repo(tmp_path, {
        "src/repro/serving/worker.py":
            fixture("bad/degradation_swallow.py")})
    report = run_analysis(repo, only=["degradation-hygiene"])
    by_rule = {}
    for f in report.findings:
        by_rule.setdefault(f.rule, []).append(f)
    assert len(by_rule["bare-except"]) == 1
    # flush's silent pass + drain's broad tuple; fan_back's
    # set_exception handler is accounted for and must NOT fire
    assert len(by_rule["swallowed-exception"]) == 2
    assert {f.symbol for f in by_rule["swallowed-exception"]} == \
        {"flush", "drain"}


def test_degradation_good_fixture_is_clean(tmp_path):
    repo = make_repo(tmp_path, {
        "src/repro/serving/worker.py":
            fixture("good/degradation_clean.py")})
    assert run_analysis(repo, only=["degradation-hygiene"]).clean


# ----------------------------------------------------------------------
# replica-state-machine
# ----------------------------------------------------------------------
def test_replica_state_bad_fixture_fires_per_write(tmp_path):
    repo = make_repo(tmp_path, {
        "src/repro/serving/replicaset.py":
            fixture("bad/replica_direct_state.py")})
    report = run_analysis(repo, only=["replica-state-machine"])
    assert rules_fired(report) == ["direct-state-write"]
    # kill's `_state`, recover's public `state`, HeartbeatLoop.tick —
    # the supervisor's own `_transition` write must NOT fire
    assert len(report.findings) == 3
    assert {f.symbol for f in report.findings} == \
        {"kill", "recover", "HeartbeatLoop.tick"}


def test_replica_state_good_fixture_is_clean(tmp_path):
    repo = make_repo(tmp_path, {
        "src/repro/serving/replicaset.py":
            fixture("good/replica_transitions.py")})
    assert run_analysis(repo, only=["replica-state-machine"]).clean


def test_replica_state_rule_scopes_to_serving_only(tmp_path):
    """A `_state` attribute elsewhere (e.g. a parser) is not a replica
    lifecycle slot — the rule is a serving-plane contract."""
    repo = make_repo(tmp_path, {
        "src/repro/analysis/walker.py":
            "class W:\n"
            "    def reset(self):\n"
            "        self._state = 0\n"})
    assert run_analysis(repo, only=["replica-state-machine"]).clean


def test_degradation_rule_scopes_to_serving_only(tmp_path):
    """checkpoint/ and analysis/ may use broad handlers with their own
    conventions — the rule is a serving-plane contract."""
    repo = make_repo(tmp_path, {
        "src/repro/checkpoint/io.py":
            "def load(p):\n"
            "    try:\n"
            "        return open(p).read()\n"
            "    except Exception:\n"
            "        return None\n"})
    assert run_analysis(repo, only=["degradation-hygiene"]).clean


# ----------------------------------------------------------------------
# suppression comments
# ----------------------------------------------------------------------
_WALL = ("import time\n"
         "\n"
         "def stamp():\n"
         "    return time.time()\n")


def test_unsuppressed_finding_fires(tmp_path):
    repo = make_repo(tmp_path, {"src/repro/serving/t.py": _WALL})
    report = run_analysis(repo, only=["async-safety"])
    assert rules_fired(report) == ["monotonic-time"]
    assert not report.suppressed


@pytest.mark.parametrize("variant", [
    "    return time.time()  # routerlint: disable=monotonic-time\n",
    "    # routerlint: disable-next-line=monotonic-time\n"
    "    return time.time()\n",
    "    return time.time()  # routerlint: disable=all\n",
    "    return time.time()  "
    "# routerlint: disable=other-rule, monotonic-time\n",
])
def test_suppression_comment_variants_silence_the_finding(
        tmp_path, variant):
    src = _WALL.replace("    return time.time()\n", variant)
    repo = make_repo(tmp_path, {"src/repro/serving/t.py": src})
    report = run_analysis(repo, only=["async-safety"])
    assert report.clean
    assert [f.rule for f in report.suppressed] == ["monotonic-time"]


def test_suppression_for_a_different_rule_does_not_silence(tmp_path):
    src = _WALL.replace(
        "    return time.time()\n",
        "    return time.time()  # routerlint: disable=precision-dtype\n")
    repo = make_repo(tmp_path, {"src/repro/serving/t.py": src})
    report = run_analysis(repo, only=["async-safety"])
    assert rules_fired(report) == ["monotonic-time"]


# ----------------------------------------------------------------------
# baseline lifecycle: add -> grandfather -> fix -> stale entry error
# ----------------------------------------------------------------------
def test_baseline_lifecycle(tmp_path):
    repo = make_repo(tmp_path, {"src/repro/serving/t.py": _WALL})
    # 1. adopt: the finding exists, write it into a baseline
    first = run_analysis(repo, only=["async-safety"])
    assert len(first.findings) == 1
    bl_path = tmp_path / "routerlint_baseline.json"
    write_baseline(bl_path, first.findings)

    # 2. grandfathered: same repo + baseline -> clean, finding baselined
    baseline = load_baseline(bl_path)
    second = run_analysis(repo, baseline=baseline, only=["async-safety"])
    assert second.clean
    assert [f.rule for f in second.baselined] == ["monotonic-time"]

    # 3. unrelated edits above the finding do NOT orphan the entry
    #    (fingerprint is line-number independent)
    shifted = make_repo(tmp_path / "shifted", {
        "src/repro/serving/t.py": "import sys\n" + _WALL})
    third = run_analysis(shifted, baseline=baseline,
                         only=["async-safety"])
    assert third.clean and len(third.baselined) == 1

    # 4. fix the finding but keep the entry -> stale-baseline ERROR
    fixed = make_repo(tmp_path / "fixed", {
        "src/repro/serving/t.py":
            _WALL.replace("time.time()", "time.monotonic()")})
    fourth = run_analysis(fixed, baseline=baseline,
                          only=["async-safety"])
    assert not fourth.clean
    assert rules_fired(fourth) == ["stale-baseline"]
    assert fourth.summary()["stale_baseline"] == 1
    assert "monotonic-time" in fourth.findings[0].message

    # 5. regenerate -> empty baseline, clean again
    write_baseline(bl_path, [])
    fifth = run_analysis(fixed, baseline=load_baseline(bl_path),
                         only=["async-safety"])
    assert fifth.clean and not fifth.baselined


def test_baseline_version_mismatch_is_rejected(tmp_path):
    p = tmp_path / "bl.json"
    p.write_text(json.dumps({"version": 99, "entries": []}))
    with pytest.raises(ValueError, match="baseline version"):
        load_baseline(p)


# ----------------------------------------------------------------------
# JSON report schema stability
# ----------------------------------------------------------------------
def test_json_report_schema_is_stable(tmp_path):
    repo = make_repo(tmp_path, {"src/repro/serving/t.py": _WALL})
    rec = report_to_json(run_analysis(repo, only=["async-safety"]))
    assert rec["version"] == JSON_REPORT_VERSION == 1
    assert rec["tool"] == "routerlint"
    assert set(rec) == {"version", "tool", "rules", "findings", "summary"}
    assert set(rec["summary"]) == {"files_scanned", "findings",
                                   "suppressed", "baselined",
                                   "stale_baseline"}
    (finding,) = rec["findings"]
    assert set(finding) == {"rule", "path", "line", "col", "symbol",
                            "message", "line_text"}
    assert finding["rule"] == "monotonic-time"
    assert finding["path"] == "src/repro/serving/t.py"
    assert finding["symbol"] == "stamp"
    assert finding["line_text"] == "return time.time()"
    json.dumps(rec)  # must be serializable as-is


def test_every_rule_has_a_registered_description():
    rules = all_rules()
    assert set(CHECKERS) == {"jit-purity", "kernel-contract",
                             "async-safety", "schema-migration",
                             "precision-hygiene", "degradation-hygiene",
                             "replica-state-machine"}
    expected = {"jit-branch-on-traced", "jit-host-call",
                "jit-closure-params", "kernel-missing-ref",
                "kernel-missing-parity-test", "kernel-blockspec-dynamic",
                "async-blocking-call", "async-global-state",
                "monotonic-time", "schema-migration-chain",
                "schema-version-literal", "precision-dtype",
                "bare-except", "swallowed-exception",
                "direct-state-write"}
    assert set(rules) == expected
    assert all(rules[r] for r in rules)


# ----------------------------------------------------------------------
# CLI exit codes + artifact output
# ----------------------------------------------------------------------
def test_cli_exit_codes_and_json_output(tmp_path, capsys):
    files = {"src/repro/serving/t.py": _WALL}
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src)

    out = tmp_path / "routerlint.json"
    rc = lint_main([str(tmp_path), "--format", "json",
                    "--output", str(out), "--only", "async-safety"])
    assert rc == 1                      # findings -> exit 1
    rec = json.loads(out.read_text())
    assert rec["summary"]["findings"] == 1
    capsys.readouterr()

    # --write-baseline adopts the finding; the next run is clean
    assert lint_main([str(tmp_path), "--write-baseline",
                      "--only", "async-safety"]) == 0
    assert lint_main([str(tmp_path), "--only", "async-safety"]) == 0
    # --no-baseline reports it again
    assert lint_main([str(tmp_path), "--no-baseline",
                      "--only", "async-safety"]) == 1
    capsys.readouterr()

    assert lint_main(["--list-rules"]) == 0
    assert "monotonic-time" in capsys.readouterr().out
    assert lint_main([str(tmp_path), "--only", "nope"]) == 2


# ----------------------------------------------------------------------
# live-repo self-check (and the regression lock for this PR's fixes)
# ----------------------------------------------------------------------
def test_live_repo_is_clean_modulo_baseline():
    """The committed tree passes its own lint.  This single assertion is
    the regression lock for every invariant the checkers encode — e.g.
    reintroducing time.time() in launch/, dropping a kernel's *_ref
    twin, or reading params from closure in a jit body fails tier-1."""
    repo = load_repo(REPO_ROOT)
    bl_path = REPO_ROOT / "routerlint_baseline.json"
    baseline = load_baseline(bl_path) if bl_path.is_file() else None
    report = run_analysis(repo, baseline=baseline)
    details = "\n".join(f"{f.path}:{f.line}: {f.rule}: {f.message}"
                        for f in report.findings)
    assert report.clean, f"routerlint findings on the live repo:\n{details}"


def test_live_launch_and_serving_planes_use_monotonic_clocks():
    """This PR replaced wall-clock time.time() interval timing in
    launch/serve.py, launch/train.py and launch/dryrun.py with
    perf_counter; pin the whole serving+launch plane to zero
    monotonic-time findings so the fix cannot regress."""
    repo = load_repo(REPO_ROOT)
    report = run_analysis(repo, only=["async-safety"])
    wall = [f for f in report.findings if f.rule == "monotonic-time"]
    assert wall == []
    # the scan actually covered the fixed modules
    scanned = {m.path for m in repo.modules}
    for mod in ("src/repro/launch/serve.py", "src/repro/launch/train.py",
                "src/repro/launch/dryrun.py",
                "src/repro/serving/batcher.py",
                "src/repro/serving/service.py"):
        assert mod in scanned


def test_live_kernel_parity_contract_holds():
    """Every Pallas kernel module has its *_ref twin registered in
    kernels/ref.py AND referenced from tests/test_kernels.py (satellite
    2: similarity_top1_ref gained its direct parity test in this PR)."""
    repo = load_repo(REPO_ROOT)
    report = run_analysis(repo, only=["kernel-contract"])
    assert report.clean, [f.message for f in report.findings]


def test_module_entrypoint_runs_clean_on_live_repo():
    """`python -m repro.analysis` (the CI invocation) exits 0."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(REPO_ROOT),
         "--format", "json"],
        capture_output=True, text=True, cwd=str(REPO_ROOT),
        env={**os.environ, "PYTHONPATH": str(REPO_ROOT / "src")})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    rec = json.loads(proc.stdout)
    assert rec["tool"] == "routerlint" and rec["findings"] == []
