"""Context-aware latent predictor (paper Eq. 12–16)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.features import K_FEATURES, extract_features, extract_features_batch
from repro.core.predictor import (
    PredictorConfig,
    apply_heads,
    cluster_dimensions,
    encode,
    init_encoder_params,
    init_head_params,
    init_predictor,
    predictor_loss,
)


def test_feature_shapes_and_signal():
    f_short = extract_features("What is 2 + 2?")
    f_long = extract_features(
        "Prove that the eigendecomposition of the combinatorial Laplacian "
        "((nested (brackets))) converges, assuming the thermodynamic limit "
        "holds, because the heterogeneous spectrum is diagonalizable, "
        "whereas the isomorphism preserves 17 distinct invariants.")
    assert f_short.shape == (K_FEATURES,)
    assert f_long[0] > f_short[0]        # longer
    assert f_long[6] > f_short[6]        # deeper nesting
    assert f_long[9] > f_short[9]        # more rare words
    batch = extract_features_batch(["a?", "b!"])
    assert batch.shape == (2, K_FEATURES)
    assert np.isfinite(batch).all()


def test_cluster_partition_exact_cover():
    rng = np.random.default_rng(0)
    # two correlated groups of dims
    z1, z2 = rng.normal(0, 1, (2, 500))
    alpha = np.stack([z1, z1 + 0.1 * rng.normal(size=500),
                      z2, z2 + 0.1 * rng.normal(size=500),
                      rng.normal(0, 1, 500), rng.normal(0, 1, 500)], 1)
    clusters = cluster_dimensions(alpha, 3)
    all_dims = np.sort(np.concatenate(clusters))
    assert np.array_equal(all_dims, np.arange(6)), "must partition exactly"
    # the two strongly correlated pairs should be co-clustered
    def cluster_of(d):
        return next(i for i, c in enumerate(clusters) if d in c)
    assert cluster_of(0) == cluster_of(1)
    assert cluster_of(2) == cluster_of(3)


def test_encoder_mask_invariance():
    cfg = PredictorConfig(vocab_size=100, max_len=8, d_model=32, num_layers=1,
                          num_heads=2, d_ff=64)
    params = init_encoder_params(jax.random.key(0), cfg)
    ids = jnp.array([[1, 5, 7, 0, 0, 0, 0, 0]])
    mask = jnp.array([[1, 1, 1, 0, 0, 0, 0, 0]], jnp.float32)
    e1 = encode(params, ids, mask, cfg)
    ids2 = ids.at[0, 5].set(42)          # padding content must not matter
    e2 = encode(params, ids2, mask, cfg)
    assert jnp.allclose(e1, e2, atol=1e-5)


def test_heads_shapes_and_residual_difficulty():
    cfg = PredictorConfig(vocab_size=100, max_len=8, d_model=32, num_layers=1,
                          num_heads=2, d_ff=64, latent_dim=10, n_clusters=3)
    clusters = [np.array([0, 1, 2, 3]), np.array([4, 5, 6]), np.array([7, 8, 9])]
    b_mean = np.linspace(-1, 1, 10)
    p = init_head_params(jax.random.key(1), cfg, clusters, b_mean)
    e_se = jnp.zeros((4, 32))
    e_st = jnp.zeros((4, cfg.n_struct))
    a_hat, b_hat = apply_heads(p, e_se, e_st, clusters, 10)
    assert a_hat.shape == (4, 10) and b_hat.shape == (4, 10)
    assert bool(jnp.all(a_hat >= 0)), "discrimination must be non-negative"
    # with zero inputs the heads output ≈ b̄ (residual parameterization)
    assert jnp.allclose(b_hat[0], jnp.asarray(b_mean), atol=0.5)


def test_predictor_loss_decreases_one_batch():
    cfg = PredictorConfig(vocab_size=200, max_len=12, d_model=32, num_layers=1,
                          num_heads=2, d_ff=64, latent_dim=6, n_clusters=2)
    clusters = [np.array([0, 1, 2]), np.array([3, 4, 5])]
    rng = np.random.default_rng(0)
    params = init_predictor(jax.random.key(0), cfg, clusters, np.zeros(6))
    batch = {
        "ids": jnp.asarray(rng.integers(1, 200, (16, 12))),
        "mask": jnp.ones((16, 12), jnp.float32),
        "feats": jnp.asarray(rng.normal(0, 1, (16, 11)).astype(np.float32)),
        "alpha": jnp.asarray(np.abs(rng.normal(1, 0.3, (16, 6))).astype(np.float32)),
        "b": jnp.asarray(rng.normal(0, 1, (16, 6)).astype(np.float32)),
    }
    from repro.optim import AdamConfig, adam_update, init_adam_state
    adam = AdamConfig(lr=1e-3)
    opt = init_adam_state(params, adam)
    losses = []
    for _ in range(30):
        (l, _), g = jax.value_and_grad(predictor_loss, has_aux=True)(
            params, batch, cfg, clusters)
        params, opt, _ = adam_update(g, opt, params, adam)
        losses.append(float(l))
    assert losses[-1] < losses[0] * 0.8
