"""Cost (Eq. 6–10) and latency (Eq. 11) estimation."""
import numpy as np

from repro.core.cost import calibrate_length_table
from repro.core.latency import calibrate_latency
from repro.data.tokenizer import HashTokenizer, model_token_count, model_tokenizer


def test_length_table_lookup_bins():
    rng = np.random.default_rng(0)
    N, M = 400, 3
    s = rng.normal(0, 1, N)
    # model m's length = (m+1) * (100 + 50*s): monotone in s
    lengths = np.stack([(m + 1) * (100 + 50 * s) for m in range(M)])
    tbl = calibrate_length_table(s, lengths, [f"m{m}" for m in range(M)], n_bins=6)
    # lookup at extreme difficulties respects ordering
    lo = tbl.lookup(np.arange(M), np.array([-2.0]))[:, 0]
    hi = tbl.lookup(np.arange(M), np.array([2.0]))[:, 0]
    assert np.all(hi > lo)
    # verbosity ordering across models preserved
    assert lo[2] > lo[1] > lo[0]


def test_length_table_add_model():
    rng = np.random.default_rng(1)
    s = rng.normal(0, 1, 200)
    lengths = np.abs(rng.normal(100, 10, (2, 200)))
    tbl = calibrate_length_table(s, lengths, ["a", "b"], n_bins=4)
    row = tbl.add_model("c", s, np.abs(rng.normal(300, 10, 200)))
    assert row == 2 and tbl.table.shape[0] == 3
    assert tbl.lookup(np.array([2]), np.array([0.0]))[0, 0] > 200


def test_latency_least_squares_recovery():
    rng = np.random.default_rng(2)
    lengths = rng.uniform(10, 500, (2, 300))
    true_ttft = np.array([0.2, 1.5])
    true_tpot = np.array([0.01, 0.05])
    lat = true_ttft[:, None] + lengths * true_tpot[:, None]
    lat += rng.normal(0, 0.01, lat.shape)
    params = calibrate_latency(lengths, lat)
    assert np.allclose(params.ttft, true_ttft, atol=0.05)
    assert np.allclose(params.tpot, true_tpot, atol=0.002)
    pred = params.predict(lengths)
    assert np.abs(pred - lat).mean() < 0.05


def test_tokenizer_deterministic_and_model_specific():
    t1 = model_tokenizer("model-a", length_factor=1.0)
    t2 = model_tokenizer("model-b", length_factor=1.3)
    text = "Compute the value of (3 + 4) * 7, then prove the bound."
    assert t1.encode(text) == t1.encode(text)
    assert model_token_count(t2, text) > model_token_count(t1, text)
    ids, mask = HashTokenizer(1000).encode_batch([text, "hi"], 16)
    assert ids.shape == (2, 16) and mask.sum(1)[1] < mask.sum(1)[0]
    assert ids.max() < 1000
