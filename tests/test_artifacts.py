"""The layered API (ISSUE 2): RouterArtifacts persistence, ModelPool
copy-on-write snapshots + JSON round-trip, the repro.api façade's typed
lifecycle errors, and churn hygiene (no length-table row leak)."""
import dataclasses
import json

import numpy as np
import pytest

from repro.api import (
    EmptyPoolError,
    NotCalibratedError,
    Policy,
    Router,
    RouterConfig,
    UnknownModelError,
)
from repro.core import IRTConfig, PredictorConfig
from repro.core.artifacts import RouterArtifacts
from repro.core.errors import DuplicateModelError
from repro.core.pool import ModelPool
from repro.core.router import POLICIES, RoutingConstraints
from repro.data import ID_TASKS, OOD_TASKS
from repro.data.tokenizer import HashTokenizer, TokenizerSpec, model_tokenizer


@pytest.fixture(scope="module")
def demo():
    """A small calibrated router with a 4-model pool + OOD eval texts."""
    from repro.launch.serve import build_demo_router

    world, router = build_demo_router(seed=0)
    qi = world.query_indices(OOD_TASKS)
    texts = [world.queries[i].text for i in qi[:24]]
    return world, router, texts


# ---------------------------------------------------------------------------
# artifact round-trips
# ---------------------------------------------------------------------------


def test_artifacts_roundtrip_bitwise(demo, tmp_path):
    """save → load reproduces every array bit-for-bit and the configs."""
    _, router, _ = demo
    art = router.artifacts
    art.save(str(tmp_path / "art"))
    back = RouterArtifacts.load(str(tmp_path / "art"))
    np.testing.assert_array_equal(art.alpha, back.alpha)
    np.testing.assert_array_equal(art.b, back.b)
    np.testing.assert_array_equal(art.anchor_idx, back.anchor_idx)
    np.testing.assert_array_equal(art.bin_edges, back.bin_edges)
    np.testing.assert_array_equal(art.theta_prior_mean, back.theta_prior_mean)
    assert art.predictor_cfg == back.predictor_cfg
    assert art.profiling == back.profiling
    assert art.tokenizer_spec == back.tokenizer_spec
    for c1, c2 in zip(art.clusters, back.clusters):
        np.testing.assert_array_equal(c1, c2)
    leaves1 = [np.asarray(x) for x in _leaves(art.predictor_params)]
    leaves2 = [np.asarray(x) for x in _leaves(back.predictor_params)]
    assert len(leaves1) == len(leaves2)
    for a, b in zip(leaves1, leaves2):
        np.testing.assert_array_equal(a, b)


def _leaves(tree):
    import jax
    return jax.tree_util.tree_leaves(tree)


def test_router_save_open_identical_routing(demo, tmp_path):
    """The acceptance contract: a saved-and-reopened router produces
    identical selections and bit-identical cost/latency tensors."""
    _, router, texts = demo
    router.save(str(tmp_path / "router"))
    back = Router.open(str(tmp_path / "router"))
    assert back.pool.names == router.pool.names
    assert back.pool.version == router.pool.version
    for pol in POLICIES:
        n1, s1, d1 = router.route(texts, policy=pol)
        n2, s2, d2 = back.route(texts, policy=pol)
        np.testing.assert_array_equal(s1, s2)
        assert n1 == n2
        np.testing.assert_array_equal(d1["cost"], d2["cost"])
        np.testing.assert_array_equal(d1["latency"], d2["latency"])
        np.testing.assert_array_equal(d1["p"], d2["p"])


def test_router_open_restores_config(demo, tmp_path):
    """Retraining on an opened router must use the calibration-time
    hyperparameters, not silent defaults."""
    _, router, _ = demo
    router.save(str(tmp_path / "r"))
    back = Router.open(str(tmp_path / "r"))
    assert back.cfg == router.cfg
    assert back.cfg.predictor.d_model == 96      # the demo's non-default
    # explicit override still wins
    forced = Router.open(str(tmp_path / "r"), cfg=RouterConfig())
    assert forced.cfg == RouterConfig()


def test_set_predictor_requires_tokenizer_on_latent_only():
    rng = np.random.default_rng(0)
    art = RouterArtifacts(
        alpha=np.abs(rng.normal(size=(30, 4))), b=rng.normal(size=(30, 4)),
        anchor_idx=np.arange(10), theta_prior_mean=np.zeros(4),
        bin_edges=np.array([-0.5, 0.5]), length_global_mean=128.0,
        profiling=RouterConfig().profiling)
    r = Router(artifacts=art)
    fake = type("P", (), {"cfg": PredictorConfig(), "params": {},
                          "clusters": [], "feat_stats": (0, 1)})()
    with pytest.raises(NotCalibratedError, match="tokenizer"):
        r.set_predictor(fake)


def test_pool_json_roundtrip_bitwise(demo):
    _, router, _ = demo
    pool = router.pool
    back = ModelPool.from_json(json.loads(json.dumps(pool.to_json())))
    s1, s2 = pool.snapshot(), back.snapshot()
    assert s1.names == s2.names and s1.version == s2.version
    np.testing.assert_array_equal(s1.thetas, s2.thetas)
    np.testing.assert_array_equal(s1.table, s2.table)
    np.testing.assert_array_equal(s1.edges, s2.edges)
    np.testing.assert_array_equal(s1.lam_in, s2.lam_in)
    np.testing.assert_array_equal(s1.lam_out, s2.lam_out)
    np.testing.assert_array_equal(s1.ttft, s2.ttft)
    np.testing.assert_array_equal(s1.tpot, s2.tpot)
    assert s1.tokenizer_specs == s2.tokenizer_specs


def test_latent_only_artifacts_roundtrip(tmp_path):
    """Artifacts without a predictor persist and refuse query work."""
    rng = np.random.default_rng(0)
    art = RouterArtifacts(
        alpha=np.abs(rng.normal(size=(30, 4))), b=rng.normal(size=(30, 4)),
        anchor_idx=np.arange(10), theta_prior_mean=np.zeros(4),
        bin_edges=np.array([-0.5, 0.5]), length_global_mean=128.0,
        profiling=dataclasses.replace(RouterConfig().profiling, steps=20))
    art.save(str(tmp_path / "latent"))
    back = RouterArtifacts.load(str(tmp_path / "latent"))
    assert not back.has_predictor
    with pytest.raises(NotCalibratedError):
        back.predict_latents(["hi"])
    # but model profiling works (characterization is decoupled)
    prof = back.profile_model(rng.random(10), rng.integers(1, 99, 10),
                              rng.random(10))
    assert prof.theta.shape == (4,) and prof.length_row.shape == (3,)


# ---------------------------------------------------------------------------
# ModelPool semantics
# ---------------------------------------------------------------------------


def _profile(D=4, K=3, seed=0):
    from repro.core.artifacts import ModelProfile
    rng = np.random.default_rng(seed)
    return ModelProfile(theta=rng.normal(size=D).astype(np.float32),
                        length_row=rng.uniform(10, 200, K),
                        ttft=0.2, tpot=0.01)


def test_pool_copy_on_write_and_versions():
    pool = ModelPool(np.array([-0.5, 0.5]))
    assert len(pool) == 0 and pool.version == 0
    pool.onboard("a", _profile(seed=1), 1.0, 2.0, TokenizerSpec(1000))
    snap1 = pool.snapshot()
    pool.onboard("b", _profile(seed=2), 3.0, 4.0,
                 TokenizerSpec(1000, salt="b", length_factor=1.1))
    snap2 = pool.snapshot()
    # handed-out snapshots are immutable; versions are monotone
    assert snap1.names == ("a",) and snap2.names == ("a", "b")
    assert (snap1.version, snap2.version) == (1, 2)
    assert snap1.thetas.shape == (1, 4) and snap2.thetas.shape == (2, 4)
    pool.update_pricing("a", price_out=9.0)
    assert pool.snapshot().lam_out[0, 0] == 9.0
    assert snap2.lam_out[0, 0] == 2.0          # old snapshot untouched
    pool.remove("a")
    assert pool.names == ("b",) and pool.version == 4
    np.testing.assert_array_equal(pool.snapshot().thetas, snap2.thetas[1:])


def test_pool_churn_reclaims_table_rows():
    """onboard → remove → onboard cycles keep the table at pool size
    (the seed's OutputLengthTable leaked one row per removed model)."""
    pool = ModelPool(np.array([0.0]))
    pool.onboard("keep", _profile(K=2), 1, 1, TokenizerSpec(100))
    for k in range(10):
        pool.onboard(f"churn{k}", _profile(K=2, seed=k), 1, 1,
                     TokenizerSpec(100))
        assert pool.snapshot().table.shape == (2, 2)
        pool.remove(f"churn{k}")
    snap = pool.snapshot()
    assert snap.table.shape == (1, 2)
    assert snap.version == 21


def test_pool_typed_errors():
    pool = ModelPool(np.array([0.0]))
    with pytest.raises(UnknownModelError):
        pool.remove("ghost")
    with pytest.raises(UnknownModelError):
        pool.update_pricing("ghost", price_in=1.0)
    pool.onboard("m", _profile(K=2), 1, 1, TokenizerSpec(100))
    with pytest.raises(DuplicateModelError):
        pool.onboard("m", _profile(K=2), 1, 1, TokenizerSpec(100))


def test_update_pricing_changes_cost_only(demo):
    _, router, texts = demo
    name = router.pool.names[0]
    p1, c1, l1 = router.score(texts)
    old_in = float(router.pool.snapshot().lam_in[0, 0])
    router.update_pricing(name, price_in=old_in * 10)
    try:
        p2, c2, l2 = router.score(texts)
        np.testing.assert_array_equal(p1, p2)
        np.testing.assert_array_equal(l1, l2)
        assert (c2[0] > c1[0]).all()
        np.testing.assert_array_equal(c1[1:], c2[1:])
    finally:
        router.update_pricing(name, price_in=old_in)


# ---------------------------------------------------------------------------
# façade lifecycle + Policy
# ---------------------------------------------------------------------------


def test_typed_lifecycle_errors(demo):
    blank = Router()
    with pytest.raises(NotCalibratedError):
        blank.route(["q"])
    with pytest.raises(NotCalibratedError):
        blank.onboard("m", np.zeros(3), np.zeros(3), np.zeros(3), 1, 1,
                      HashTokenizer(100))
    # pre-calibration pool reads stay well-typed, never AttributeError
    assert len(blank.pool) == 0 and blank.pool.version == 0
    with pytest.raises(UnknownModelError):
        blank.pool.remove("ghost")
    _, router, texts = demo
    empty = Router(artifacts=router.artifacts)    # calibrated, no models
    with pytest.raises(EmptyPoolError):
        empty.route(texts[:2])
    from repro.serving import RouterEngine
    with pytest.raises(EmptyPoolError):
        RouterEngine(empty).route_batch(texts[:2])
    with pytest.raises(NotCalibratedError):
        RouterEngine(Router())


def test_policy_resolution():
    assert Policy.of("balanced").weights == POLICIES["balanced"]
    assert Policy.of("max_acc").name == "max_acc"
    custom = Policy.of(weights=(0.6, 0.3, 0.1))
    assert custom.name == "custom"
    with pytest.raises(ValueError, match="unknown policy"):
        Policy.of("warp_speed")
    capped = Policy.of("min_cost").constrained(max_total_cost=1.0)
    assert capped.constraints == RoutingConstraints(max_total_cost=1.0)
    # Policy.of passes an existing policy through, overriding as asked
    assert Policy.of(capped) is capped
    re_w = Policy.of(capped, weights=(1.0, 0.0, 0.0))
    assert re_w.weights == (1.0, 0.0, 0.0)
    assert re_w.constraints == capped.constraints


def test_policy_object_routes_like_string(demo):
    _, router, texts = demo
    _, s1, _ = router.route(texts, policy="min_cost")
    _, s2, _ = router.route(texts, policy=Policy.of("min_cost"))
    np.testing.assert_array_equal(s1, s2)
    # constraints travel inside the Policy
    cap = float(np.sort(router.score(texts)[1], 0)[0].sum()) * 2
    pol = Policy.of("max_acc").constrained(max_total_cost=cap)
    _, sel, diag = router.route(texts, policy=pol)
    used = float(diag["cost"][np.asarray(sel), np.arange(len(texts))].sum())
    assert used <= cap * 1.1


def test_instance_calibrate_honors_instance_cfg():
    """router.calibrate(R) (the seed idiom) must calibrate THAT router
    with ITS cfg — not silently build a default-config throwaway."""
    rng = np.random.default_rng(0)
    R = (rng.random((30, 60)) > 0.5).astype(np.float32)
    cfg = RouterConfig(
        irt=IRTConfig(dim=4, epochs=30), n_anchors=10,
        predictor=PredictorConfig(d_model=32, num_layers=1, d_ff=64,
                                  max_len=16, latent_dim=4, n_clusters=2))
    r = Router(cfg=cfg)
    out = r.calibrate(R)
    assert out is r
    assert r.artifacts is not None and r.artifacts.n_anchors == 10
    # the classmethod idiom builds a fresh router with the given cfg
    r2 = Router.calibrate(R, cfg=cfg)
    assert r2 is not r and r2.artifacts.n_anchors == 10


def test_route_batch_honors_policy_constraints(demo):
    """A Policy carrying constraints must not be silently unconstrained
    on the serving hot path (it falls through to the Lagrangian route)."""
    from repro.serving import RouterEngine, RouterEngineConfig

    _, router, texts = demo
    engine = RouterEngine(router, RouterEngineConfig(cache_size=0))
    cap = float(np.sort(router.score(texts)[1], 0)[0].sum()) * 2
    pol = Policy.of("max_acc").constrained(max_total_cost=cap)
    _, sel_ref, diag = router.route(texts, policy=pol)
    names, sel = engine.route_batch(texts, policy=pol)
    np.testing.assert_array_equal(np.asarray(sel_ref), sel)
    used = float(diag["cost"][np.asarray(sel), np.arange(len(texts))].sum())
    assert used <= cap * 1.1


def test_zerorouter_shim_matches_facade(demo):
    """The deprecated shim is a thin view over the same layers."""
    from repro.core import ZeroRouter

    _, router, texts = demo
    with pytest.warns(DeprecationWarning):
        zr = ZeroRouter()
    zr._router = router
    np.testing.assert_array_equal(zr.alpha, router.artifacts.alpha)
    assert [m.name for m in zr.pool] == list(router.pool.names)
    assert zr.pool_version == router.pool.version
    _, s1, _ = zr.route(texts, policy="balanced")
    _, s2, _ = router.route(texts, policy="balanced")
    np.testing.assert_array_equal(s1, s2)
    p1, c1, l1 = zr.score_queries(texts)
    p2, c2, l2 = router.score(texts)
    np.testing.assert_array_equal(p1, p2)
    np.testing.assert_array_equal(c1, c2)


# ---------------------------------------------------------------------------
# schema versioning (ISSUE 3 persistence satellite)
# ---------------------------------------------------------------------------


def test_artifact_schema_version_roundtrip(demo, tmp_path):
    """Every saved artifact records its schema_version; same-version (and
    version-less legacy) records load; a NEWER version raises the typed
    SchemaVersionError instead of misreading."""
    from repro.checkpoint import ARTIFACT_SCHEMA_VERSION
    from repro.core.errors import SchemaVersionError

    _, router, texts = demo
    d = tmp_path / "router"
    router.save(str(d))
    meta_path = d / "artifacts.meta.json"
    meta = json.loads(meta_path.read_text())
    assert meta["schema_version"] == ARTIFACT_SCHEMA_VERSION

    # legacy record (pre-versioning): reads as version 1
    legacy = dict(meta)
    del legacy["schema_version"]
    meta_path.write_text(json.dumps(legacy))
    _, sel_legacy, _ = Router.open(str(d)).route(texts)

    # newer-than-supported: typed refusal naming both versions
    newer = dict(meta, schema_version=ARTIFACT_SCHEMA_VERSION + 1)
    meta_path.write_text(json.dumps(newer))
    with pytest.raises(SchemaVersionError) as ei:
        Router.open(str(d))
    assert ei.value.found == ARTIFACT_SCHEMA_VERSION + 1
    assert ei.value.supported == ARTIFACT_SCHEMA_VERSION

    # restore → routes identically to the reference
    meta_path.write_text(json.dumps(meta))
    _, sel_back, _ = Router.open(str(d)).route(texts)
    _, sel_ref, _ = router.route(texts)
    np.testing.assert_array_equal(np.asarray(sel_back), np.asarray(sel_ref))
    np.testing.assert_array_equal(np.asarray(sel_legacy),
                                  np.asarray(sel_ref))


def test_pool_schema_version_roundtrip(demo):
    from repro.core.errors import SchemaVersionError
    from repro.core.pool import POOL_SCHEMA_VERSION

    _, router, _ = demo
    rec = router.pool.to_json()
    assert rec["schema_version"] == POOL_SCHEMA_VERSION
    # legacy (version-less) pool records still load
    legacy = {k: v for k, v in rec.items() if k != "schema_version"}
    assert ModelPool.from_json(legacy).names == router.pool.names
    with pytest.raises(SchemaVersionError):
        ModelPool.from_json(dict(rec,
                                 schema_version=POOL_SCHEMA_VERSION + 1))
