"""Per-architecture smoke tests: REDUCED configs, one forward + one train
step on CPU, asserting shapes + finiteness; plus exact prefill/decode parity
against the full-sequence forward."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import apply_model, init_params
from repro.optim import AdamConfig, init_adam_state
from repro.runtime import train_step


def _batch_for(cfg, key, B=2, L=16):
    ks = jax.random.split(key, 2)
    batch = {"tokens": jax.random.randint(ks[0], (B, L + 1), 0, cfg.vocab_size)}
    pre = None
    if cfg.frontend is not None:
        fe = cfg.frontend
        pre = 0.1 * jax.random.normal(
            ks[1], (B, fe.num_prefix_tokens, fe.frontend_dim), jnp.float32)
        batch["prefix_emb"] = pre
    return batch, pre


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    key = jax.random.key(0)
    params = init_params(cfg, key)
    batch, pre = _batch_for(cfg, key)

    logits, aux = apply_model(params, cfg, batch["tokens"][:, :-1],
                              mode="train", prefix_emb=pre)
    P = cfg.frontend.num_prefix_tokens if cfg.frontend is not None else 0
    assert logits.shape == (2, 16 + P, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    adam = AdamConfig(lr=1e-3)
    opt = init_adam_state(params, adam)
    p2, o2, metrics = train_step(params, opt, batch, cfg, adam, remat=False)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_parity(arch):
    """decode(one token | prefill cache) == full-forward logits.

    Run in f32 so the assertion tests cache/state *semantics*, not bf16
    rounding of the mixed-precision attention paths."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config(arch),
                              act_dtype="float32", param_dtype="float32")
    key = jax.random.key(1)
    params = init_params(cfg, key)
    B, L = 2, 16
    batch, pre = _batch_for(cfg, key, B, L - 1)
    toks = batch["tokens"]  # (B, L)
    P = cfg.frontend.num_prefix_tokens if cfg.frontend is not None else 0

    logits_full, _ = apply_model(params, cfg, toks, mode="train", prefix_emb=pre)
    _, cache, _ = apply_model(params, cfg, toks[:, :L - 1], mode="prefill",
                              prefix_emb=pre, cache_capacity=P + L)
    cur = jnp.full((B,), P + L - 1, jnp.int32)
    logits_dec, cache2, _ = apply_model(params, cfg, toks[:, L - 1:L],
                                        mode="decode", cache=cache, cur_pos=cur)
    diff = float(jnp.max(jnp.abs(
        logits_dec.astype(jnp.float32) - logits_full[:, P + L - 1].astype(jnp.float32))))
    assert diff < 0.05, f"{arch}: decode parity broken, diff={diff}"
    # cache structures must round-trip through decode
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def test_full_configs_match_assignment():
    """Exact paper-table values for the assigned architectures."""
    spec = {
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    }
    for arch, (L, d, H, kv, ff, V) in spec.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == H, arch
        assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == V, arch
    assert get_config("kimi-k2-1t-a32b").moe.num_experts == 384
    assert get_config("kimi-k2-1t-a32b").moe.num_experts_per_tok == 8
    assert get_config("deepseek-v2-lite-16b").moe.num_experts == 64
    assert get_config("deepseek-v2-lite-16b").moe.num_experts_per_tok == 6
    assert get_config("deepseek-v2-lite-16b").mla.kv_lora_rank == 512
    assert get_config("hymba-1.5b").ssm.state_size == 16
    assert get_config("qwen2-72b").qkv_bias


def test_window_variant_configs():
    """Beyond-paper: '-sw' sliding-window serving variants for dense archs
    enable long_500k decode; inapplicable families must refuse."""
    from repro.configs import get_config, get_smoke_config, window_variant
    cfg = get_config("llama3-405b-sw")
    assert cfg.attention_kind == "sliding" and cfg.is_sub_quadratic()
    assert cfg.sliding_window == 4096 and cfg.global_every == 8
    # numerics: the reduced variant still decodes consistently
    import dataclasses
    scfg = dataclasses.replace(get_smoke_config("llama3-405b-sw"),
                               act_dtype="float32", param_dtype="float32")
    key = jax.random.key(0)
    params = init_params(scfg, key)
    toks = jax.random.randint(key, (1, 12), 0, scfg.vocab_size)
    full, _ = apply_model(params, scfg, toks, mode="train")
    _, cache, _ = apply_model(params, scfg, toks[:, :11], mode="prefill",
                              cache_capacity=12)
    dec, _, _ = apply_model(params, scfg, toks[:, 11:], mode="decode",
                            cache=cache, cur_pos=jnp.array([11]))
    assert float(jnp.max(jnp.abs(dec - full[:, 11]))) < 0.05
    # MLA/SSM variants must be rejected
    import pytest as _pytest
    with _pytest.raises(ValueError):
        window_variant(get_config("deepseek-v2-lite-16b"))
