"""Minimal offline stand-in for the ``hypothesis`` API surface these tests
use (``given`` / ``settings`` / ``strategies``).

The container has no network access, so ``hypothesis`` may be absent; the
property tests then degrade to a deterministic sweep: each ``@given`` test
runs ``_N_EXAMPLES`` examples drawn from the declared strategies with a
fixed seed, plus the strategy minima (the most shrink-like corner).  That
keeps every property exercised — just without adaptive shrinking.

Usage in a test module::

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:                       # offline container
        from _hypothesis_fallback import given, settings, st
"""
from __future__ import annotations

import functools
import inspect
from typing import Any, Callable, List, Sequence

import numpy as np

_N_EXAMPLES = 10


class _Strategy:
    """A value generator: ``minimum()`` plus seeded ``example(rng)``."""

    def __init__(self, minimum: Callable[[], Any],
                 example: Callable[[np.random.Generator], Any]):
        self.minimum = minimum
        self.example = example


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            minimum=lambda: min_value,
            example=lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value: float, max_value: float, **_: Any) -> _Strategy:
        return _Strategy(
            minimum=lambda: min_value,
            example=lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def sampled_from(elements: Sequence[Any]) -> _Strategy:
        elements = list(elements)
        return _Strategy(
            minimum=lambda: elements[0],
            example=lambda rng: elements[int(rng.integers(len(elements)))])

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(minimum=lambda: False,
                         example=lambda rng: bool(rng.integers(2)))

    @staticmethod
    def lists(elem: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def example(rng: np.random.Generator) -> List[Any]:
            n = int(rng.integers(min_size, max_size + 1))
            return [elem.example(rng) for _ in range(n)]
        return _Strategy(
            minimum=lambda: [elem.minimum() for _ in range(min_size)],
            example=example)


st = _Strategies()


def settings(**_: Any):
    """Accepted and ignored (no shrinking/deadline machinery here)."""
    def deco(fn):
        return fn
    return deco


def given(*strategies: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            fn(*args, *[s.minimum() for s in strategies], **kwargs)
            for i in range(_N_EXAMPLES):
                rng = np.random.default_rng(i)
                fn(*args, *[s.example(rng) for s in strategies], **kwargs)
        # hide the strategy-bound (trailing) parameters from pytest so it
        # does not try to resolve them as fixtures
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        wrapper.__signature__ = sig.replace(  # type: ignore[attr-defined]
            parameters=params[: len(params) - len(strategies)])
        del wrapper.__wrapped__
        return wrapper
    return deco


def _selftest() -> None:
    seen = set()
    rng = np.random.default_rng(0)
    for _ in range(50):
        seen.add(st.integers(0, 3).example(rng))
    assert seen == {0, 1, 2, 3}
    assert st.lists(st.integers(1, 2), min_size=2, max_size=2).minimum() == [1, 1]


if __name__ == "__main__":
    _selftest()
    print("ok")
