"""Shared fixtures. Session-scoped world/calibration amortize the cost of
the heavier integration tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.irt import IRTConfig, fit_irt, posterior_means
from repro.data import WorldConfig, build_world, calibration_pool, calibration_responses, ID_TASKS


@pytest.fixture(scope="session")
def small_world():
    return build_world(WorldConfig(queries_per_task=40, n_future_models=6, seed=0))


@pytest.fixture(scope="session")
def demo_stack():
    """The smoke-world (world, router, engine) the serving-layer tests
    share.  Session-scoped: calibration is the expensive part.  Tests
    that mutate the pool / artifacts MUST restore them (try/finally) —
    version numbers may advance, so assert on relative versions only."""
    from repro.launch.serve import build_demo_engine

    world, router, engine = build_demo_engine(seed=0)
    return world, router, engine


@pytest.fixture(scope="session")
def calibrated(small_world):
    world = small_world
    qi = world.query_indices(ID_TASKS)
    thetas = calibration_pool(world, 80)
    R = calibration_responses(world, thetas, qi)
    post, trace = fit_irt(jnp.asarray(R), IRTConfig(dim=20, epochs=800, seed=0))
    pm = posterior_means(post)
    return {
        "world": world,
        "qi": qi,
        "thetas_cal": thetas,
        "responses": R,
        "post": pm,
        "trace": np.asarray(trace),
    }
