"""Shared fixtures. Session-scoped world/calibration amortize the cost of
the heavier integration tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.irt import IRTConfig, fit_irt, posterior_means
from repro.data import WorldConfig, build_world, calibration_pool, calibration_responses, ID_TASKS


@pytest.fixture(scope="session")
def small_world():
    return build_world(WorldConfig(queries_per_task=40, n_future_models=6, seed=0))


@pytest.fixture(scope="session")
def calibrated(small_world):
    world = small_world
    qi = world.query_indices(ID_TASKS)
    thetas = calibration_pool(world, 80)
    R = calibration_responses(world, thetas, qi)
    post, trace = fit_irt(jnp.asarray(R), IRTConfig(dim=20, epochs=800, seed=0))
    pm = posterior_means(post)
    return {
        "world": world,
        "qi": qi,
        "thetas_cal": thetas,
        "responses": R,
        "post": pm,
        "trace": np.asarray(trace),
    }
