"""Closed-loop health: circuit breaker, EWMA re-profiling, pool schema
v2 migration, metrics registry, ranked top-k serving parity, and the
outcome-feedback wire op (PR 6)."""
import json
import time

import numpy as np
import pytest

from repro.core.errors import EmptyPoolError, SchemaVersionError
from repro.core.pool import (BREAKER_CLOSED, BREAKER_HALF_OPEN, BREAKER_OPEN,
                             POOL_SCHEMA_VERSION, HealthPolicy, ModelPool)


def _tiny_pool(n: int = 3, policy: HealthPolicy = None) -> ModelPool:
    from repro.core.artifacts import ModelProfile
    from repro.data.tokenizer import TokenizerSpec

    edges = np.array([0.0, 16.0, 64.0, 256.0], np.float64)
    pool = ModelPool(edges)
    rng = np.random.default_rng(0)
    for i in range(n):
        pool.onboard(
            f"m{i}",
            ModelProfile(theta=rng.normal(size=8).astype(np.float32),
                         length_row=np.full(len(edges) + 1, 100.0 + 10 * i),
                         ttft=0.2 + 0.1 * i, tpot=0.01 * (i + 1)),
            price_in=0.5 + i, price_out=1.0 + i,
            tokenizer=TokenizerSpec(vocab_size=32_000, salt=f"m{i}"))
    if policy is not None:
        pool.set_health_policy(policy)
    return pool


# ---------------------------------------------------------------------------
# circuit breaker state machine
# ---------------------------------------------------------------------------

def test_breaker_opens_after_threshold_and_masks():
    pool = _tiny_pool(policy=HealthPolicy(failure_threshold=3,
                                          open_cooldown_s=30.0))
    t = 1000.0
    for i in range(2):
        info = pool.record_outcome("m1", ok=False, now=t)
        assert info["state_after"] == "closed" and info["transition"] is None
    info = pool.record_outcome("m1", ok=False, now=t)
    assert info["transition"] == "closed->open"
    assert pool.snapshot().breaker[1] == BREAKER_OPEN
    mask = pool.snapshot().routable_mask(now=t + 1.0)
    np.testing.assert_array_equal(mask, [True, False, True])
    # success resets the consecutive-failure count while closed
    pool.record_outcome("m0", ok=False, now=t)
    pool.record_outcome("m0", ok=False, now=t)
    pool.record_outcome("m0", ok=True, now=t)
    for _ in range(2):
        info = pool.record_outcome("m0", ok=False, now=t)
    assert info["state_after"] == "closed", "success must reset the count"


def test_breaker_half_open_recovery_and_reopen():
    pol = HealthPolicy(failure_threshold=2, open_cooldown_s=10.0,
                       half_open_probes=2)
    pool = _tiny_pool(policy=pol)
    t = 2000.0
    pool.record_outcome("m2", ok=False, now=t)
    pool.record_outcome("m2", ok=False, now=t)
    assert pool.snapshot().breaker[2] == BREAKER_OPEN
    # inside the cooldown: still masked, state untouched by routable_mask
    assert not pool.snapshot().routable_mask(now=t + 5.0)[2]
    # past the cooldown: probe traffic admitted WITHOUT mutating state
    assert pool.snapshot().routable_mask(now=t + 11.0)[2]
    assert pool.snapshot().breaker[2] == BREAKER_OPEN
    # first post-cooldown outcome materializes half-open
    info = pool.record_outcome("m2", ok=True, now=t + 11.0)
    assert info["transition"] == "open->half_open"
    assert pool.snapshot().breaker[2] == BREAKER_HALF_OPEN
    # a half-open failure slams it shut again, cooldown restarts
    info = pool.record_outcome("m2", ok=False, now=t + 12.0)
    assert info["transition"] == "half_open->open"
    assert not pool.snapshot().routable_mask(now=t + 13.0)[2]
    # full recovery: cooldown → two successful probes → closed
    info = pool.record_outcome("m2", ok=True, now=t + 23.0)
    assert info["state_after"] == "half_open"
    info = pool.record_outcome("m2", ok=True, now=t + 24.0)
    assert info["transition"] == "half_open->closed"
    assert pool.snapshot().breaker[2] == BREAKER_CLOSED


def test_record_outcome_is_copy_on_write():
    pool = _tiny_pool()
    snap_before = pool.snapshot()
    v = pool.version
    pool.record_outcome("m0", ok=False, now=0.0)
    assert pool.version == v + 1
    assert snap_before.consec_failures[0] == 0, "pinned snapshot mutated"
    assert pool.snapshot().consec_failures[0] == 1


def test_record_outcome_is_race_free_through_half_open():
    """Concurrent reporters hammering a HALF_OPEN breaker: without the
    pool's outcome lock, two probe successes both read probes=0 and
    neither closes the breaker (and obs/EWMA updates are lost to
    read-copy-bump races).  With it, the state machine walks
    open -> half_open -> closed exactly once and every report lands."""
    import threading

    pol = HealthPolicy(failure_threshold=2, open_cooldown_s=10.0,
                       half_open_probes=2)
    pool = _tiny_pool(policy=pol)
    t = 5000.0
    pool.record_outcome("m1", ok=False, now=t)
    pool.record_outcome("m1", ok=False, now=t)
    assert pool.snapshot().breaker[1] == BREAKER_OPEN

    n_threads, per_thread = 8, 25
    infos, errors = [], []
    lock = threading.Lock()
    start = threading.Barrier(n_threads)

    def report():
        try:
            start.wait(timeout=10)
            mine = [pool.record_outcome("m1", ok=True, now=t + 11.0)
                    for _ in range(per_thread)]
            with lock:
                infos.extend(mine)
        except Exception as e:  # noqa: BLE001 — surfaced via assert below
            errors.append(e)

    threads = [threading.Thread(target=report) for _ in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=30)
    assert not errors
    assert len(infos) == n_threads * per_thread
    transitions = [i["transition"] for i in infos if i["transition"]]
    assert sorted(transitions) == ["half_open->closed", "open->half_open"], \
        f"breaker transitioned more than once under contention: {transitions}"
    snap = pool.snapshot()
    assert snap.breaker[1] == BREAKER_CLOSED
    # no lost updates: every single report's copy-on-write bump landed
    assert snap.obs_count[1] == 2 + n_threads * per_thread
    assert snap.consec_failures[1] == 0


# ---------------------------------------------------------------------------
# EWMA latency re-profiling
# ---------------------------------------------------------------------------

def test_ewma_reprofiling_converges():
    """Feeding outcomes that consistently run 2× the predicted latency
    must converge ttft/tpot toward the observed regime."""
    pool = _tiny_pool(policy=HealthPolicy(ewma_alpha=0.2))
    s0 = pool.snapshot()
    tokens = 100
    target = 2.0 * (s0.ttft[0, 0] + tokens * s0.tpot[0, 0])
    for _ in range(60):
        pool.record_outcome("m0", ok=True, latency_s=float(target),
                            tokens=tokens, now=0.0)
    s1 = pool.snapshot()
    predicted = s1.ttft[0, 0] + tokens * s1.tpot[0, 0]
    assert abs(predicted - target) / target < 0.02
    assert abs(s1.ewma_lat_ratio[0] - 1.0) < 0.05, \
        "once re-profiled, observed/predicted must hover at 1"
    # other models untouched
    assert s1.ttft[1, 0] == s0.ttft[1, 0]
    assert s1.obs_count[0] == 60 and s1.obs_count[1] == 0


def test_outcome_without_latency_skips_reprofiling():
    pool = _tiny_pool()
    s0 = pool.snapshot()
    pool.record_outcome("m0", ok=True, now=0.0)
    s1 = pool.snapshot()
    assert s1.ttft[0, 0] == s0.ttft[0, 0]
    assert s1.tpot[0, 0] == s0.tpot[0, 0]


# ---------------------------------------------------------------------------
# pool schema v1 <-> v2
# ---------------------------------------------------------------------------

def test_pool_v2_roundtrip_preserves_health():
    pool = _tiny_pool(policy=HealthPolicy(failure_threshold=2))
    pool.record_outcome("m1", ok=False, now=50.0)
    pool.record_outcome("m1", ok=False, now=50.0)
    pool.record_outcome("m0", ok=True, latency_s=0.5, tokens=10, now=50.0)
    rec = pool.to_json()
    assert rec["schema_version"] == POOL_SCHEMA_VERSION == 2
    back = ModelPool.from_json(json.loads(json.dumps(rec)))
    s, b = pool.snapshot(), back.snapshot()
    np.testing.assert_array_equal(b.breaker, s.breaker)
    np.testing.assert_array_equal(b.consec_failures, s.consec_failures)
    np.testing.assert_allclose(b.opened_at, s.opened_at)
    np.testing.assert_allclose(b.ewma_lat_ratio, s.ewma_lat_ratio)
    np.testing.assert_allclose(b.ttft, s.ttft)
    assert b.health_policy == s.health_policy
    assert b.breaker[1] == BREAKER_OPEN


def test_pool_v1_reads_through_migrator_and_writes_back():
    pool = _tiny_pool()
    pool.record_outcome("m0", ok=False, now=0.0)
    # downgrade writer: legacy v1 record with no health block
    rec1 = pool.to_json(schema_version=1)
    assert rec1["schema_version"] == 1
    assert "health" not in rec1 and "health_policy" not in rec1
    # v1 → v2 migrator defaults every breaker closed, default policy
    back = ModelPool.from_json(json.loads(json.dumps(rec1)))
    s = back.snapshot()
    np.testing.assert_array_equal(s.breaker, np.zeros(3, np.int8))
    assert s.health_policy == HealthPolicy()
    assert back.names == pool.names
    np.testing.assert_allclose(s.thetas, pool.snapshot().thetas)
    # and the migrated pool round-trips as v2
    again = ModelPool.from_json(back.to_json())
    np.testing.assert_array_equal(again.snapshot().breaker, s.breaker)


def test_pool_newer_schema_refuses():
    pool = _tiny_pool()
    rec = pool.to_json()
    rec["schema_version"] = POOL_SCHEMA_VERSION + 1
    with pytest.raises(SchemaVersionError):
        ModelPool.from_json(rec)
    with pytest.raises(SchemaVersionError):
        pool.to_json(schema_version=POOL_SCHEMA_VERSION + 1)


def test_artifact_migration_hook():
    """The checkpoint layer's registered-migrator chain upgrades an
    old-version artifact record at load time (synthetic version bump —
    the container format itself is still v1)."""
    import os
    import tempfile

    import repro.checkpoint.ckpt as ckpt
    from repro.checkpoint import (load_artifact, register_artifact_migration,
                                  save_artifact)

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "art")
        save_artifact(path, {"x": np.arange(4)}, meta={"m": 7})
        rec = json.load(open(path + ".meta.json"))
        rec["schema_version"] = 0          # pretend it predates v1
        json.dump(rec, open(path + ".meta.json", "w"))
        saved = dict(ckpt._ARTIFACT_MIGRATIONS)
        ckpt._ARTIFACT_MIGRATIONS.clear()
        try:
            with pytest.raises(SchemaVersionError):
                load_artifact(path)        # no migrator registered

            @register_artifact_migration(0)
            def _up(pair):
                tree, meta = pair
                tree["upgraded"] = True
                return tree, meta

            tree, meta = load_artifact(path)
            assert tree["upgraded"] and meta == {"m": 7}
            np.testing.assert_array_equal(tree["x"], np.arange(4))
            with pytest.raises(ValueError):
                register_artifact_migration(0)(lambda pair: pair)
        finally:
            ckpt._ARTIFACT_MIGRATIONS.clear()
            ckpt._ARTIFACT_MIGRATIONS.update(saved)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_registry_render():
    from repro.serving.metrics import MetricsRegistry

    reg = MetricsRegistry()
    reg.counter_inc("req_total", "requests", {"policy": "balanced"})
    reg.counter_inc("req_total", labels={"policy": "balanced"}, amount=2)
    reg.gauge_set("pool_models", 4, "pool size")
    reg.histogram_observe("lat_ms", 3.0, buckets=(1, 5, 10))
    reg.histogram_observe("lat_ms", 7.0, buckets=(1, 5, 10))
    reg.on_collect(lambda r: r.gauge_set("collected", 1.0))
    text = reg.render()
    assert 'req_total{policy="balanced"} 3' in text
    assert "# TYPE req_total counter" in text
    assert "pool_models 4" in text
    assert 'lat_ms_bucket{le="5"} 1' in text
    assert 'lat_ms_bucket{le="10"} 2' in text
    assert 'lat_ms_bucket{le="+Inf"} 2' in text
    assert "lat_ms_sum 10" in text and "lat_ms_count 2" in text
    assert "collected 1" in text, "on_collect callback must run at scrape"
    assert reg.value("req_total", {"policy": "balanced"}) == 3.0
    with pytest.raises(TypeError):
        reg.gauge_set("req_total", 1.0)    # kind mismatch refuses


# ---------------------------------------------------------------------------
# serving: ranked decisions, masking, parity (demo stack)
# ---------------------------------------------------------------------------

@pytest.fixture()
def corpus(demo_stack):
    world, _, _ = demo_stack
    from repro.data import OOD_TASKS

    qi = world.query_indices(OOD_TASKS)
    return [world.queries[i].text for i in qi[:48]]


@pytest.mark.parametrize("policy", ["balanced", "max_acc", "min_cost",
                                    "min_lat"])
def test_topk_rank0_matches_router_route(demo_stack, corpus, policy):
    """With every breaker closed, rank 0 of the ranked top-k decision is
    BIT-identical to the scalar reference path (Router.route) under
    every built-in policy — the PR-5 selection contract."""
    _, router, engine = demo_stack
    _, sel_ref, _ = router.route(corpus, policy=policy)
    dec = engine.route_pinned(corpus, policy=policy, k=4)
    assert dec.ranked is not None and dec.ranked.shape == (4, len(corpus))
    np.testing.assert_array_equal(dec.ranked[0], np.asarray(sel_ref))
    np.testing.assert_array_equal(dec.sel, np.asarray(sel_ref))
    # ranks are distinct models per query
    assert all(len(set(dec.ranked[:, j])) == 4
               for j in range(len(corpus)))


def test_engine_masks_open_breaker_and_fails_over(demo_stack, corpus):
    world, router, engine = demo_stack
    snap_before = router.pool._snap
    try:
        router.pool.set_health_policy(HealthPolicy(failure_threshold=1))
        names0, sel0 = engine.route_batch(corpus, policy="balanced")
        victim = names0[int(sel0[0])]
        router.pool.record_outcome(victim, ok=False)
        names1, sel1 = engine.route_batch(corpus, policy="balanced")
        assert victim not in {names1[int(s)] for s in sel1}
        dec = engine.route_pinned(corpus, policy="balanced", k=4)
        vidx = dec.model_names.index(victim)
        assert not np.any(dec.ranked == vidx), \
            "open breaker leaked into the ranked list"
        # k clamps to the routable count (one of the 4 models is masked)
        assert dec.ranked.shape[0] == len(dec.model_names) - 1
    finally:
        router.pool._snap = snap_before


def test_all_breakers_open_raises_empty_pool(demo_stack, corpus):
    _, router, engine = demo_stack
    snap_before = router.pool._snap
    try:
        router.pool.set_health_policy(
            HealthPolicy(failure_threshold=1, open_cooldown_s=1e6))
        for name in router.pool.names:
            router.pool.record_outcome(name, ok=False)
        with pytest.raises(EmptyPoolError):
            engine.route_batch(corpus[:4], policy="balanced")
    finally:
        router.pool._snap = snap_before


def test_constrained_route_respects_breaker_mask(demo_stack, corpus):
    """The constrained (non-fused) path applies the same breaker mask:
    a permissive budget keeps every live model eligible, yet the open
    breaker still keeps the victim out of the selections."""
    from repro.api import Policy

    _, router, engine = demo_stack
    snap_before = router.pool._snap
    pol = Policy.of("balanced").constrained(max_total_cost=1e9)
    try:
        router.pool.set_health_policy(HealthPolicy(failure_threshold=1))
        names0, sel0 = engine.route_batch(corpus, policy="balanced")
        victim = names0[int(sel0[0])]
        router.pool.record_outcome(victim, ok=False)
        dec = engine.route_pinned(corpus, policy=pol)
        assert victim not in {dec.model_names[int(s)] for s in dec.sel}
        assert dec.ranked is not None and dec.ranked.shape[0] == 1
    finally:
        router.pool._snap = snap_before


# ---------------------------------------------------------------------------
# service plane: outcome feedback + metrics over the wire
# ---------------------------------------------------------------------------

def test_report_outcome_and_metrics_over_wire(demo_stack, corpus):
    from repro.serving import BackgroundServer, ServiceConfig
    from repro.serving.protocol import ServiceClient

    world, router, engine = demo_stack
    snap_before = router.pool._snap
    try:
        router.pool.set_health_policy(
            HealthPolicy(failure_threshold=2, open_cooldown_s=0.2,
                         half_open_probes=1))
        with BackgroundServer(router, engine=engine,
                              cfg=ServiceConfig(max_batch=16,
                                                max_wait_s=0.001)) as srv:
            with ServiceClient(srv.host, srv.port) as client:
                resps = client.route_many(corpus[:8])
                assert all(r.ranked and r.ranked[0] == r.model
                           for r in resps)
                victim = resps[0].model
                client.report_outcome("r0", victim, ok=False)
                info = client.report_outcome("r1", victim, ok=False)
                assert info["transition"] == "closed->open"
                assert info["request_id"] == "r1"
                # zero routing errors while the victim is masked
                resps2 = client.route_many(corpus[:8])
                assert all(r.ok and r.model != victim for r in resps2)
                # recovery through a single probe
                time.sleep(0.3)
                info = client.report_outcome("r2", victim, ok=True,
                                             latency_ms=50.0, tokens=8)
                assert info["state_after"] in ("half_open", "closed")
                m = client.metrics()
                assert 'router_outcomes_total{model="%s",ok="false"} 2' \
                    % victim in m
                assert 'router_breaker_transitions_total{model="%s",' \
                    'to="open"} 1' % victim in m
                assert "router_pool_models_healthy" in m
                assert "router_requests_total" in m
                assert "router_request_compute_ms_bucket" in m
    finally:
        router.pool._snap = snap_before
