"""RouterService async serving plane (ISSUE 3): typed request/response
routing, admission control, live pool administration with snapshot
pinning, the JSONL wire protocol, and the fresh-process TCP acceptance
path against ``launch/serve.py --listen``."""
import asyncio
import dataclasses
import json
import time
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.errors import (DeadlineExceededError, OverloadedError,
                               SchemaVersionError)
from repro.data import ID_TASKS, OOD_TASKS
from repro.serving import (BackgroundServer, RouteRequest, RouterEngine,
                           RouterEngineConfig, RouterService, ServiceClient,
                           ServiceConfig)
from repro.serving import protocol as proto
from repro.serving.engine import BatchDecision


@pytest.fixture(scope="module")
def served(demo_stack):
    world, router, engine = demo_stack
    qi = world.query_indices(OOD_TASKS)
    texts = [world.queries[i].text for i in qi[:32]]
    return world, router, engine, texts


def _future_model_responses(world, router, name="future-model-00"):
    m = world.model_index(name)
    anchors = world.query_indices(ID_TASKS)[router.artifacts.anchor_idx]
    y = world.sample_responses([m], anchors, seed=m)[0]
    lens = world.output_lengths([m], anchors)[0]
    lats = world.true_latency([m], anchors, lens[None])[0]
    return world.models[m], y, lens, lats


# ---------------------------------------------------------------------------
# engine: pinned decisions + warm-start
# ---------------------------------------------------------------------------


def test_route_pinned_matches_route(served):
    _, router, engine, texts = served
    dec = engine.route_pinned(texts)
    names_ref, sel_ref, _ = router.route(texts)
    np.testing.assert_array_equal(dec.sel, np.asarray(sel_ref))
    assert dec.names == names_ref
    assert dec.pool_version == router.pool.version
    assert dec.model_names == router.pool.names
    # the diagnostics path must select identically and carry (M, Q) scores
    full = engine.route_pinned(texts, want_scores=True)
    np.testing.assert_array_equal(full.sel, dec.sel)
    assert full.p.shape == (len(router.pool), len(texts))


def test_warmup_precompiles_first_request(served):
    """After warmup, the first singleton route must not trigger a fresh
    jit trace (compilation would be ~100× the steady-state latency)."""
    _, router, _, texts = served
    engine = RouterEngine(router, RouterEngineConfig(cache_size=0))
    warm_s = engine.warmup()
    assert warm_s > 0
    t0 = time.perf_counter()
    names, sel = engine.route_batch([texts[0]])
    first_s = time.perf_counter() - t0
    names_ref, sel_ref, _ = router.route([texts[0]])
    assert names == names_ref and int(sel[0]) == int(np.asarray(sel_ref)[0])
    # generous bound: steady-state is ~5-10ms; an un-warmed first call
    # pays seconds of XLA compilation
    assert first_s < max(1.0, warm_s / 2), \
        f"first routed request stalled {first_s:.2f}s after warmup"


# ---------------------------------------------------------------------------
# service plane (in-process, asyncio)
# ---------------------------------------------------------------------------


def test_submit_matches_router_route(served):
    _, router, engine, texts = served

    async def main():
        async with RouterService(router, engine=engine) as svc:
            resps = await svc.submit_many(texts)
            one = await svc.submit(texts[0])
            return resps, one

    resps, one = asyncio.run(main())
    names_ref, sel_ref, _ = router.route(texts)
    assert [r.model for r in resps] == names_ref
    assert [r.model_index for r in resps] == [int(s) for s in
                                              np.asarray(sel_ref)]
    assert all(r.ok and r.pool_version == router.pool.version
               for r in resps)
    assert one.model == names_ref[0]
    assert one.queued_ms >= 0 and one.compute_ms > 0


def test_stream_completion_order_and_ids(served):
    _, router, engine, texts = served

    async def main():
        async with RouterService(router, engine=engine) as svc:
            reqs = [RouteRequest(t, request_id=str(i))
                    for i, t in enumerate(texts[:12])]
            return [r async for r in svc.stream(reqs)]

    resps = asyncio.run(main())
    assert len(resps) == 12 and all(r.ok for r in resps)
    assert sorted(int(r.request_id) for r in resps) == list(range(12))
    by_id = {int(r.request_id): r for r in resps}
    names_ref, _, _ = router.route(texts[:12])
    assert [by_id[i].model for i in range(12)] == names_ref


def test_per_request_policy_override(served):
    """Mixed policies in one service: each request is routed under ITS
    policy (the batcher splits per-policy sub-batches)."""
    _, router, engine, texts = served

    async def main():
        async with RouterService(router, engine=engine) as svc:
            return await asyncio.gather(
                svc.submit_many([RouteRequest(t, policy="min_cost")
                                 for t in texts[:8]]),
                svc.submit_many([RouteRequest(t, policy="max_acc")
                                 for t in texts[:8]]))

    cost_r, acc_r = asyncio.run(main())
    _, sel_cost, _ = router.route(texts[:8], policy="min_cost")
    _, sel_acc, _ = router.route(texts[:8], policy="max_acc")
    assert [r.model_index for r in cost_r] == [int(s) for s in
                                               np.asarray(sel_cost)]
    assert [r.model_index for r in acc_r] == [int(s) for s in
                                              np.asarray(sel_acc)]


def test_diagnostics_fan_back(served):
    _, router, engine, texts = served

    async def main():
        async with RouterService(router, engine=engine) as svc:
            return await svc.submit(RouteRequest(texts[0],
                                                 diagnostics=True))

    resp = asyncio.run(main())
    assert set(resp.diagnostics) == set(router.pool.names)
    p, cost, lat = router.score([texts[0]])
    for i, name in enumerate(router.pool.names):
        d = resp.diagnostics[name]
        assert d["p"] == pytest.approx(float(p[i, 0]), abs=2e-6)
        assert d["cost"] == float(cost[i, 0])
        assert d["latency"] == float(lat[i, 0])


class _SlowStubEngine:
    """Engine double: fixed decision after a delay (admission tests)."""

    def __init__(self, delay_s=0.05):
        self.delay_s = delay_s
        self.cache_stats = None

    def route_pinned(self, texts, policy="balanced", want_scores=False):
        time.sleep(self.delay_s)
        return BatchDecision(names=["m0"] * len(texts),
                             sel=np.zeros(len(texts), int),
                             pool_version=0, model_names=("m0",))


def _stub_router():
    snap = SimpleNamespace(version=0, n_models=1, names=("m0",))
    return SimpleNamespace(pool=SimpleNamespace(snapshot=lambda: snap))


def test_admission_overload_sheds_typed(served):
    """max_inflight=1 + max_queue=1: one routes, one waits, the rest are
    shed with a typed OverloadedError — never queued unboundedly."""

    async def main():
        svc = RouterService(_stub_router(), engine=_SlowStubEngine(),
                            cfg=ServiceConfig(max_batch=1, max_wait_s=0.0,
                                              max_inflight=1, max_queue=1))
        async with svc:
            results = await svc.submit_many(["a", "b", "c", "d"],
                                            return_exceptions=True)
        return results, svc.stats()

    results, stats = asyncio.run(main())
    ok = [r for r in results if not isinstance(r, BaseException)]
    shed = [r for r in results if isinstance(r, OverloadedError)]
    assert len(ok) == 2 and len(shed) == 2
    assert stats["shed_overloaded"] == 2 and stats["completed"] == 2


def test_deadline_shed_before_compute(served):
    _, router, engine, texts = served

    async def main():
        async with RouterService(router, engine=engine) as svc:
            with pytest.raises(DeadlineExceededError):
                await svc.submit(RouteRequest(texts[0], deadline_s=0.0))
            # in-band form: stream folds the shed into a typed status
            resps = [r async for r in svc.stream(
                [RouteRequest(texts[0], deadline_s=0.0, request_id="x")])]
            return resps, svc.stats()

    resps, stats = asyncio.run(main())
    assert resps[0].status == "deadline_exceeded" and not resps[0].ok
    assert resps[0].model_index == -1
    assert stats["shed_deadline"] == 2


def test_admin_swap_predictor_live(served):
    """swap_predictor through the admin plane: new artifacts identity,
    engine clears its latent cache, selections stay consistent."""
    _, router, _, texts = served
    engine = RouterEngine(router, RouterEngineConfig(cache_size=64))
    old_art, old_pred = router.artifacts, router.predictor

    async def main():
        async with RouterService(router, engine=engine) as svc:
            before = await svc.submit_many(texts[:8])
            info = svc.admin.swap_predictor(
                dataclasses.replace(old_pred))   # identity-equal swap
            after = await svc.submit_many(texts[:8])
            return before, info, after

    try:
        before, info, after = asyncio.run(main())
        assert router.artifacts is not old_art
        assert [r.model for r in before] == [r.model for r in after]
        assert info["pool_version"] == router.pool.version
    finally:
        router.artifacts = old_art


# ---------------------------------------------------------------------------
# wire protocol
# ---------------------------------------------------------------------------


def test_frame_roundtrip_sync_reader():
    import io

    frames = [{"op": "ping"}, {"op": "route", "text": "héllo\nworld",
                               "id": "a"}]
    buf = io.BytesIO(b"".join(proto.encode_frame(f) for f in frames))
    got = []
    while True:
        f = proto.read_frame_sync(buf)
        if f is None:
            break
        got.append(f)
    assert got == frames


def test_policy_codec_roundtrip():
    from repro.api import Policy

    for pol in ("balanced",
                Policy.of("min_cost"),
                Policy.of("max_acc").constrained(max_total_cost=0.5),
                Policy((0.7, 0.2, 0.1))):
        enc = proto.policy_to_json(pol)
        json.dumps(enc)   # must be pure JSON
        dec = proto.policy_from_json(enc)
        assert dec == pol


def test_status_raises_typed_errors():
    with pytest.raises(OverloadedError):
        proto._raise_for_status({"status": "overloaded", "error": "x"})
    with pytest.raises(DeadlineExceededError):
        proto._raise_for_status({"status": "deadline_exceeded"})
    from repro.core.errors import DuplicateModelError, ServiceError
    with pytest.raises(DuplicateModelError):
        proto._raise_for_status({"status": "error", "error": "dup",
                                 "error_type": "DuplicateModelError"})
    with pytest.raises(ServiceError):
        proto._raise_for_status({"status": "error", "error": "boom",
                                 "error_type": "NoSuchError"})


# ---------------------------------------------------------------------------
# TCP end-to-end (in-process server thread)
# ---------------------------------------------------------------------------


def test_tcp_roundtrip_with_admin_midstream(served):
    """The ISSUE-3 acceptance core: a client on the TCP JSONL transport
    routes queries, onboards a model via the admin plane mid-stream, and
    selections before/after match ``Router.route`` bit-for-bit for the
    pinned snapshot versions."""
    world, router, engine, texts = served
    mi, y, lens, lats = _future_model_responses(world, router)

    with BackgroundServer(router, engine=engine) as srv:
        with ServiceClient(srv.host, srv.port) as client:
            assert client.ping()["op"] == "pong"
            v0 = router.pool.version
            pre = client.route_many(texts)
            _, sel_pre, _ = router.route(texts)
            assert [r.model_index for r in pre] == \
                [int(s) for s in np.asarray(sel_pre)]
            assert all(r.pool_version == v0 for r in pre)
            # streaming shape: one frame per query, coalesced server-side
            # (selections depend on coalesced-batch composition, so only
            # the fan-back contract is asserted here)
            piped = client.route_many(texts[:8], pipeline=True)
            assert [r.text for r in piped] == list(texts[:8])
            assert all(r.ok and r.model == router.pool.names[r.model_index]
                       for r in piped)
            try:
                info = client.admin.onboard(
                    "future-model-00", y, lens, lats,
                    mi.price_in, mi.price_out, mi.tokenizer)
                assert info["pool_version"] == v0 + 1
                assert "future-model-00" in info["models"]
                post = client.route_many(texts)
                _, sel_post, _ = router.route(texts)
                assert [r.model_index for r in post] == \
                    [int(s) for s in np.asarray(sel_post)]
                assert all(r.pool_version == v0 + 1 for r in post)
                # pricing mutation bumps again; stats see the live pool
                client.admin.update_pricing("future-model-00",
                                            price_in=123.0)
                assert client.stats()["pool_version"] == v0 + 2
            finally:
                if "future-model-00" in router.pool:
                    client.admin.remove("future-model-00")
            from repro.core.errors import UnknownModelError
            with pytest.raises(UnknownModelError):
                client.admin.remove("future-model-00")
            # a malformed route frame must still be ANSWERED (typed
            # error), or a pipelined client would hang counting responses
            client._send({"op": "route", "id": "bad"})   # no "text"
            rep = client._recv()
            assert rep["id"] == "bad" and rep["status"] == "error"
            # and the connection stays usable afterwards
            assert client.route(texts[0]).ok


# ---------------------------------------------------------------------------
# fresh-process acceptance: launch/serve.py --listen
# ---------------------------------------------------------------------------


def test_fresh_process_tcp_serving(served, tmp_path):
    """Spawn ``launch/serve.py --mode route --listen`` on a saved
    artifact in a FRESH process; this process acts as the remote client:
    route → onboard via wire admin → route, matching a local
    ``Router.open`` reference bit-for-bit."""
    import os
    import subprocess
    import sys
    import threading

    world, router, engine, texts = served
    art_dir = tmp_path / "router_artifact"
    router.save(str(art_dir))
    from repro.api import Router
    ref = Router.open(str(art_dir))

    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH")) if p)
    pro = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--mode", "route",
         "--listen", "127.0.0.1:0", "--artifact", str(art_dir)],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        bufsize=1, env=env)
    addr = {}
    lines = []

    def _watch():
        for line in pro.stdout:
            lines.append(line)
            if line.startswith("LISTENING "):
                host, _, port = line.split()[1].rpartition(":")
                addr["host"], addr["port"] = host, int(port)
                return

    w = threading.Thread(target=_watch, daemon=True)
    w.start()
    try:
        w.join(timeout=120)
        assert addr, f"server never came up:\n{''.join(lines)}"
        with proto.connect(addr["host"], addr["port"]) as client:
            pre = client.route_many(texts)
            _, sel_ref, _ = ref.route(texts)
            assert [r.model_index for r in pre] == \
                [int(s) for s in np.asarray(sel_ref)]
            mi, y, lens, lats = _future_model_responses(world, ref)
            client.admin.onboard("future-model-00", y, lens, lats,
                                 mi.price_in, mi.price_out, mi.tokenizer)
            ref.onboard("future-model-00", y, lens, lats, mi.price_in,
                        mi.price_out, mi.tokenizer)
            post = client.route_many(texts)
            _, sel_post, _ = ref.route(texts)
            assert [r.model_index for r in post] == \
                [int(s) for s in np.asarray(sel_post)], \
                "post-onboard selections diverged from the local reference"
            assert ref.pool.version == pre[0].pool_version + 1 \
                == post[0].pool_version
    finally:
        pro.terminate()
        try:
            pro.wait(timeout=10)
        except subprocess.TimeoutExpired:
            pro.kill()
