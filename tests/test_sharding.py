"""Sharding planner: divisibility fallbacks + logical-axes assignment."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import abstract_mesh

from repro.configs import get_config, get_smoke_config
from repro.models import abstract_params
from repro.models.model import abstract_cache
from repro.sharding.axes import cache_axes, param_axes, tree_pspecs
from repro.sharding.planner import ShardingCtx, rules_with


def _mesh(shape=(16, 16), axes=("data", "model")):
    return abstract_mesh(shape, axes)


def test_divisible_dims_shard():
    ctx = ShardingCtx(mesh=_mesh())
    spec = ctx.pspec(["batch", "heads"], (256, 128))
    assert spec == P("data", "model")


def test_indivisible_dims_fall_back():
    ctx = ShardingCtx(mesh=_mesh())
    # 8 kv heads cannot shard over 16-way model axis → replicated
    spec = ctx.pspec(["batch", "kv_heads"], (256, 8))
    assert spec == P("data", None)
    # batch=1 (long-context decode) cannot shard anywhere
    spec = ctx.pspec(["batch", None], (1, 524_288))
    assert spec == P(None, None)


def test_multi_pod_batch_axes():
    ctx = ShardingCtx(mesh=_mesh((2, 16, 16), ("pod", "data", "model")))
    spec = ctx.pspec(["batch", None], (256, 4096))
    assert spec == P(("pod", "data"), None)


def test_no_axis_reuse_within_spec():
    ctx = ShardingCtx(mesh=_mesh())
    # both dims want "model"; only one may take it
    spec = ctx.pspec(["heads", "vocab"], (128, 128_256))
    used = [s for s in spec if s is not None]
    assert len(used) == len(set(used))


def test_long_context_cache_rule_override():
    rules = rules_with(
        {"cache_seq": [("data", "model"), ("model",), ("data",), ()]})
    ctx = ShardingCtx(mesh=_mesh(), rules=rules)
    spec = ctx.pspec(["batch", "cache_seq"], (1, 524_288))
    assert spec == P(None, ("data", "model"))


def test_param_axes_cover_all_leaves():
    for arch in ("llama3-405b", "kimi-k2-1t-a32b", "hymba-1.5b", "xlstm-125m"):
        cfg = get_smoke_config(arch)
        params = abstract_params(cfg)
        axes = param_axes(params)
        leaves, treedef = jax.tree_util.tree_flatten(params)
        axes_leaves = treedef.flatten_up_to(axes)
        assert len(leaves) == len(axes_leaves)
        for leaf, ax in zip(leaves, axes_leaves):
            assert len(ax) == leaf.ndim, (leaf.shape, ax)


def test_param_pspecs_shard_big_dims_405b():
    """The full llama3-405b param tree must actually shard its big matrices
    over BOTH axes (FSDP × TP) — otherwise nothing fits."""
    cfg = get_config("llama3-405b")
    params = abstract_params(cfg)
    ctx = ShardingCtx(mesh=_mesh())
    specs = tree_pspecs(ctx, params, param_axes(params))
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    by_name = {jax.tree_util.keystr(p): s for p, s in flat}
    # embedding: vocab-only sharding (d-over-data breaks the GSPMD gather —
    # see axes.py note)
    emb = [s for n, s in by_name.items() if "embed" in n and "run" not in n][0]
    assert emb == P("model", None)
    wq = [s for n, s in by_name.items() if "w_q" in n][0]
    assert set(a for a in wq if a) == {"data", "model"} or wq[1:] == ("data", "model")


def test_cache_axes_and_specs():
    cfg = get_smoke_config("gemma3-1b")
    cache = abstract_cache(cfg, batch=32, capacity=256)
    axes = cache_axes(cache)
    ctx = ShardingCtx(mesh=_mesh((4, 2), ("data", "model")))
    specs = tree_pspecs(ctx, cache, axes)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    for path, spec in flat:
        name = jax.tree_util.keystr(path)
        if name.endswith("['k']") or name.endswith("['v']"):
            assert spec[1] == "data", f"{name}: batch dim must shard on data"


# ---------------------------------------------------------------------------
# Property tests (hypothesis): the planner must emit valid specs for ANY
# shape on ANY mesh — every assigned mesh axis divides its dim, no axis
# is used twice, and unknown logical names fall back to replication.
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:                       # offline container
    from _hypothesis_fallback import given, settings, st


@settings(max_examples=60, deadline=None)
@given(
    st.lists(st.integers(1, 2048), min_size=1, max_size=4),
    st.lists(st.sampled_from(
        ["batch", "heads", "kv_heads", "mlp", "vocab", "experts",
         "embed_fsdp", "tp", "cache_seq", "seq", None, "no_such_axis"]),
        min_size=1, max_size=4),
    st.sampled_from([(16, 16), (2, 16, 16), (4, 2), (1, 8)]),
)
def test_planner_specs_always_valid(shape, logical, mesh_shape):
    n = min(len(shape), len(logical))
    shape, logical = tuple(shape[:n]), tuple(logical[:n])
    axes_names = ("pod", "data", "model")[-len(mesh_shape):] \
        if len(mesh_shape) == 3 else ("data", "model")[:len(mesh_shape)]
    mesh = abstract_mesh(mesh_shape, axes_names)
    ctx = ShardingCtx(mesh=mesh)
    spec = ctx.pspec(logical, shape)
    used = []
    for dim, part in zip(shape, spec):
        if part is None:
            continue
        parts = part if isinstance(part, tuple) else (part,)
        size = 1
        for a in parts:
            assert a in mesh.shape, f"unknown mesh axis {a}"
            used.append(a)
            size *= mesh.shape[a]
        assert dim % size == 0, f"dim {dim} not divisible by {size} ({part})"
    assert len(used) == len(set(used)), f"axis reused: {spec}"
