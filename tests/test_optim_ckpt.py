"""Optimizer + checkpoint substrate."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.optim import AdamConfig, adam_update, exponential_decay, init_adam_state, warmup_cosine


def test_adam_minimizes_quadratic():
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    adam = AdamConfig(lr=0.1)
    opt = init_adam_state(params, adam)
    for _ in range(200):
        g = {"w": 2 * (params["w"] - target)}
        params, opt, stats = adam_update(g, opt, params, adam)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)
    assert int(opt["count"]) == 200


def test_grad_clip_limits_update():
    params = {"w": jnp.zeros(4)}
    adam = AdamConfig(lr=1.0, grad_clip_norm=1e-8)
    opt = init_adam_state(params, adam)
    g = {"w": jnp.full(4, 1e6)}
    p2, _, stats = adam_update(g, opt, params, adam)
    assert float(stats["grad_norm"]) > 1e5
    assert float(jnp.abs(p2["w"]).max()) < 1.0


def test_schedules():
    lr = exponential_decay(0.1, 0.99, 100)
    assert abs(float(lr(jnp.array(0))) - 0.1) < 1e-6
    assert abs(float(lr(jnp.array(250))) - 0.1 * 0.99 ** 2) < 1e-6
    wc = warmup_cosine(1e-3, 10, 100)
    assert float(wc(jnp.array(5))) < 1e-3
    assert float(wc(jnp.array(99))) < float(wc(jnp.array(20)))


def test_bf16_moments_dtype():
    params = {"w": jnp.zeros((8, 8), jnp.bfloat16)}
    adam = AdamConfig(lr=0.1, moment_dtype="bfloat16")
    opt = init_adam_state(params, adam)
    assert opt["mu"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    p2, o2, _ = adam_update(g, opt, params, adam)
    assert o2["nu"]["w"].dtype == jnp.bfloat16
    assert p2["w"].dtype == jnp.bfloat16


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                   "c": jnp.array(3, jnp.int32)},
    }
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, tree, {"note": "test"})
    back = load_checkpoint(path, tree)
    assert back["nested"]["b"].dtype == jnp.bfloat16
    for (p1, l1), (p2, l2) in zip(
            jax.tree_util.tree_flatten_with_path(tree)[0],
            jax.tree_util.tree_flatten_with_path(back)[0]):
        np.testing.assert_array_equal(np.asarray(l1, np.float32),
                                      np.asarray(l2, np.float32))
