"""D-optimality anchor selection (paper Eq. 3–4): greedy properties."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:                       # offline container
    from _hypothesis_fallback import given, settings, st

from repro.core.anchors import (
    greedy_doptimal,
    logdet_information,
    random_anchors,
    select_anchors,
)


@pytest.fixture(scope="module")
def alpha():
    rng = np.random.default_rng(0)
    return jnp.asarray(np.abs(rng.normal(0, 1, (300, 12))) *
                       (rng.random((300, 12)) < 0.3), dtype=jnp.float32)


def test_no_duplicates(alpha):
    idx = np.asarray(greedy_doptimal(alpha, 50))
    assert len(np.unique(idx)) == 50


def test_greedy_beats_random(alpha):
    idx = greedy_doptimal(alpha, 40)
    ld_g = float(logdet_information(alpha, idx))
    for seed in range(5):
        ld_r = float(logdet_information(
            alpha, jnp.asarray(random_anchors(alpha.shape[0], 40, seed))))
        assert ld_g >= ld_r - 1e-6, f"greedy {ld_g} < random {ld_r}"


def test_monotone_gain(alpha):
    """log det of the greedy prefix is non-decreasing (info only grows)."""
    idx = greedy_doptimal(alpha, 30)
    lds = [float(logdet_information(alpha, idx[:k])) for k in range(5, 31, 5)]
    assert all(b >= a - 1e-6 for a, b in zip(lds, lds[1:]))


def test_diminishing_returns(alpha):
    """Greedy marginal gains are (weakly) decreasing — the submodularity
    property that justifies the greedy approximation."""
    idx = np.asarray(greedy_doptimal(alpha, 40))
    A = 1e-3 * np.eye(alpha.shape[1])
    gains = []
    for i in idx:
        v = np.asarray(alpha[i])
        gains.append(np.log1p(v @ np.linalg.solve(A, v)))
        A = A + np.outer(v, v)
    gains = np.array(gains)
    # allow tiny numerical wiggle
    assert np.all(gains[1:] <= gains[:-1] + 1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 8), st.integers(10, 40), st.integers(0, 10_000))
def test_gain_positive_and_selection_valid(d, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(0, 1, (n, d)).astype(np.float32))
    k = min(n, d + 2)
    idx = np.asarray(greedy_doptimal(a, k))
    assert idx.min() >= 0 and idx.max() < n
    assert len(np.unique(idx)) == k
    ld = float(logdet_information(a, jnp.asarray(idx)))
    assert np.isfinite(ld)


def test_all_strategies_return_n(alpha):
    b = jnp.asarray(np.random.default_rng(1).normal(0, 1, alpha.shape),
                    dtype=jnp.float32)
    for strat in ("d_optimal", "random", "diff", "disc", "task_aware"):
        idx = select_anchors(strat, alpha, b, 25, seed=0)
        assert len(idx) == 25, strat
        assert len(np.unique(idx)) == 25, strat
