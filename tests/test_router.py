"""Policy-driven routing ILP (paper Eq. 17–18) — solver invariants,
including the Lagrangian solver's feasibility-repair bisection and its
``violated`` diagnostics (ISSUE 2 satellite)."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:                       # offline container
    from _hypothesis_fallback import given, settings, st

from repro.core.router import (
    POLICIES,
    RoutingConstraints,
    reward,
    route,
    route_unconstrained,
    utility_matrix,
)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 6), st.integers(1, 30), st.integers(0, 10_000))
def test_unconstrained_is_exact(M, Q, seed):
    """Per-query argmax solves the separable ILP exactly: no assignment has
    higher total utility."""
    rng = np.random.default_rng(seed)
    util = jnp.asarray(rng.normal(0, 1, (M, Q)).astype(np.float32))
    sel = np.asarray(route_unconstrained(util))
    total = float(util[sel, np.arange(Q)].sum())
    for _ in range(20):
        other = rng.integers(0, M, Q)
        assert float(util[other, np.arange(Q)].sum()) <= total + 1e-5


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_weights_change_behavior(seed):
    """Accuracy-first picks (weakly) more accurate, cost-first cheaper."""
    rng = np.random.default_rng(seed)
    M, Q = 5, 40
    p = rng.random((M, Q)).astype(np.float32)
    cost = rng.random((M, Q)).astype(np.float32)
    lat = rng.random((M, Q)).astype(np.float32)
    sel_acc, _ = route(p, cost, lat, policy="max_acc")
    sel_cost, _ = route(p, cost, lat, policy="min_cost")
    qi = np.arange(Q)
    assert p[np.asarray(sel_acc), qi].mean() >= p[np.asarray(sel_cost), qi].mean() - 1e-6
    assert cost[np.asarray(sel_cost), qi].mean() <= cost[np.asarray(sel_acc), qi].mean() + 1e-6


def test_constrained_respects_budget():
    rng = np.random.default_rng(0)
    M, Q = 4, 60
    p = rng.random((M, Q)).astype(np.float32)
    # model 0 accurate & expensive, model 3 cheap & weak
    p[0] += 0.5
    cost = np.stack([np.full(Q, c) for c in (10.0, 4.0, 1.0, 0.2)]).astype(np.float32)
    lat = rng.random((M, Q)).astype(np.float32)
    unlimited, _ = route(p, cost, lat, policy="max_acc")
    cost_unlimited = float(cost[np.asarray(unlimited), np.arange(Q)].sum())
    budget = cost_unlimited * 0.3
    sel, diag = route(p, cost, lat, policy="max_acc",
                      constraints=RoutingConstraints(max_total_cost=budget))
    used = float(cost[np.asarray(sel), np.arange(Q)].sum())
    assert used <= budget * 1.1, f"budget {budget} exceeded: {used}"


def _spread_instance(seed=0, M=4, Q=60):
    """p increasing with cost: budget caps force real trade-offs."""
    rng = np.random.default_rng(seed)
    p = rng.random((M, Q)).astype(np.float32)
    p[0] += 0.5
    cost = np.stack([np.full(Q, c) for c in (10.0, 4.0, 1.0, 0.2)]).astype(np.float32)
    lat = np.stack([np.full(Q, t) for t in (0.1, 0.5, 2.0, 8.0)]).astype(np.float32)
    lat += rng.random((M, Q)).astype(np.float32) * 0.05
    return p, cost, lat


def test_constrained_latency_cap_binds():
    """A binding total-latency cap must be respected and reported."""
    p, cost, lat = _spread_instance()
    Q = p.shape[1]
    free, _ = route(p, cost, lat, policy="min_cost")
    lat_free = float(lat[np.asarray(free), np.arange(Q)].sum())
    cap = lat_free * 0.3
    sel, diag = route(p, cost, lat, policy="min_cost",
                      constraints=RoutingConstraints(max_total_latency=cap))
    used = float(lat[np.asarray(sel), np.arange(Q)].sum())
    assert used <= cap * 1.1, f"latency cap {cap} exceeded: {used}"
    assert not bool(np.asarray(diag["violated"])[1])
    # the cap actually changed behavior (it was binding)
    assert used < lat_free * 0.5


def test_constrained_min_mean_accuracy():
    """The (≥) accuracy constraint pushes selections to stronger models."""
    p, cost, lat = _spread_instance()
    Q = p.shape[1]
    cheap, _ = route(p, cost, lat, policy="min_cost")
    acc_cheap = float(p[np.asarray(cheap), np.arange(Q)].mean())
    target = min(acc_cheap + 0.2, 0.95)
    sel, diag = route(p, cost, lat, policy="min_cost",
                      constraints=RoutingConstraints(min_mean_accuracy=target))
    acc = float(p[np.asarray(sel), np.arange(Q)].mean())
    assert acc >= target - 0.02, f"mean accuracy {acc} below target {target}"
    assert not bool(np.asarray(diag["violated"])[2])


def test_constrained_infeasible_cap_best_effort():
    """A cap below the cheapest possible assignment is infeasible: the
    solver must fall back to the best-effort t=64 dual scaling, still pick
    the cheapest models, and flag the violation in diagnostics."""
    p, cost, lat = _spread_instance()
    Q = p.shape[1]
    min_possible = float(cost.min(0).sum())
    cap = min_possible * 0.5               # impossible budget
    sel, diag = route(p, cost, lat, policy="max_acc",
                      constraints=RoutingConstraints(max_total_cost=cap))
    sel = np.asarray(sel)
    # best effort = cheapest model everywhere (the dual dominates utility)
    used = float(cost[sel, np.arange(Q)].sum())
    assert used <= min_possible * 1.01
    assert bool(np.asarray(diag["violated"])[0]), \
        "infeasible budget must be reported as violated"
    # usage/caps diagnostics are populated on the raw scale
    assert np.asarray(diag["usage"])[0] == pytest.approx(used, rel=1e-5)
    assert np.asarray(diag["caps"])[0] == pytest.approx(cap, rel=1e-6)


def test_constrained_inactive_constraints_noop():
    """Slack constraints must not perturb the unconstrained optimum."""
    p, cost, lat = _spread_instance()
    free, _ = route(p, cost, lat, policy="balanced")
    sel, diag = route(p, cost, lat, policy="balanced",
                      constraints=RoutingConstraints(
                          max_total_cost=1e9, max_total_latency=1e9,
                          min_mean_accuracy=0.0))
    np.testing.assert_array_equal(np.asarray(free), np.asarray(sel))
    assert not np.asarray(diag["violated"]).any()


def test_reward_matches_manual():
    p = np.array([[0.9, 0.1], [0.5, 0.8]], np.float32)
    cost = np.array([[1.0, 1.0], [0.0, 0.0]], np.float32)
    lat = np.array([[0.0, 0.0], [1.0, 1.0]], np.float32)
    sel = jnp.array([0, 1])
    w = (1.0, 0.0, 0.0)
    r = float(reward(sel, p, cost, lat, w))
    assert abs(r - (0.9 + 0.8) / 2) < 1e-6


def test_policies_registry():
    for name, w in POLICIES.items():
        assert abs(sum(w) - 1.0) < 1e-9, name
