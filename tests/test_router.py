"""Policy-driven routing ILP (paper Eq. 17–18) — solver invariants."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:                       # offline container
    from _hypothesis_fallback import given, settings, st

from repro.core.router import (
    POLICIES,
    RoutingConstraints,
    reward,
    route,
    route_unconstrained,
    utility_matrix,
)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 6), st.integers(1, 30), st.integers(0, 10_000))
def test_unconstrained_is_exact(M, Q, seed):
    """Per-query argmax solves the separable ILP exactly: no assignment has
    higher total utility."""
    rng = np.random.default_rng(seed)
    util = jnp.asarray(rng.normal(0, 1, (M, Q)).astype(np.float32))
    sel = np.asarray(route_unconstrained(util))
    total = float(util[sel, np.arange(Q)].sum())
    for _ in range(20):
        other = rng.integers(0, M, Q)
        assert float(util[other, np.arange(Q)].sum()) <= total + 1e-5


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_weights_change_behavior(seed):
    """Accuracy-first picks (weakly) more accurate, cost-first cheaper."""
    rng = np.random.default_rng(seed)
    M, Q = 5, 40
    p = rng.random((M, Q)).astype(np.float32)
    cost = rng.random((M, Q)).astype(np.float32)
    lat = rng.random((M, Q)).astype(np.float32)
    sel_acc, _ = route(p, cost, lat, policy="max_acc")
    sel_cost, _ = route(p, cost, lat, policy="min_cost")
    qi = np.arange(Q)
    assert p[np.asarray(sel_acc), qi].mean() >= p[np.asarray(sel_cost), qi].mean() - 1e-6
    assert cost[np.asarray(sel_cost), qi].mean() <= cost[np.asarray(sel_acc), qi].mean() + 1e-6


def test_constrained_respects_budget():
    rng = np.random.default_rng(0)
    M, Q = 4, 60
    p = rng.random((M, Q)).astype(np.float32)
    # model 0 accurate & expensive, model 3 cheap & weak
    p[0] += 0.5
    cost = np.stack([np.full(Q, c) for c in (10.0, 4.0, 1.0, 0.2)]).astype(np.float32)
    lat = rng.random((M, Q)).astype(np.float32)
    unlimited, _ = route(p, cost, lat, policy="max_acc")
    cost_unlimited = float(cost[np.asarray(unlimited), np.arange(Q)].sum())
    budget = cost_unlimited * 0.3
    sel, diag = route(p, cost, lat, policy="max_acc",
                      constraints=RoutingConstraints(max_total_cost=budget))
    used = float(cost[np.asarray(sel), np.arange(Q)].sum())
    assert used <= budget * 1.1, f"budget {budget} exceeded: {used}"


def test_reward_matches_manual():
    p = np.array([[0.9, 0.1], [0.5, 0.8]], np.float32)
    cost = np.array([[1.0, 1.0], [0.0, 0.0]], np.float32)
    lat = np.array([[0.0, 0.0], [1.0, 1.0]], np.float32)
    sel = jnp.array([0, 1])
    w = (1.0, 0.0, 0.0)
    r = float(reward(sel, p, cost, lat, w))
    assert abs(r - (0.9 + 0.8) / 2) < 1e-6


def test_policies_registry():
    for name, w in POLICIES.items():
        assert abs(sum(w) - 1.0) < 1e-9, name
