"""Fault-injection plane + graceful degradation (ISSUE 9): deterministic
FaultPlans, engine watchdog/retry/bisect quarantine, crash-safe
persistence under injected crashes and bit rot, resilient-client
reconnect with server-side idempotency, breaker storms, RouteLog
torn-tail recovery, and the all-families chaos soak with zero selection
divergence."""
import json
import os
import threading
import time

import numpy as np
import pytest

from repro.checkpoint import load_artifact, save_artifact
from repro.core.errors import (ArtifactCorruptError, FrameTooLargeError,
                               PoisonQueryError)
from repro.core.pool import BREAKER_CLOSED, BREAKER_OPEN
from repro.serving import MicroBatcher, RouterEngine, RouterEngineConfig
from repro.serving import faults
from repro.serving.faults import FaultEvent, FaultPlan, InjectedFault
from repro.serving.protocol import BackgroundServer, ServiceClient
from repro.serving.semcache import RouteLog
from repro.serving.service import RouterService, ServiceConfig


@pytest.fixture(autouse=True)
def _pristine_fault_state():
    """Every test starts disarmed with zeroed degradation counters, and
    cannot leak an armed plan into the rest of the suite."""
    faults.disarm()
    faults.reset_degraded()
    yield
    faults.disarm()
    faults.reset_degraded()


@pytest.fixture(scope="module")
def stack(demo_stack):
    world, router, engine = demo_stack
    from repro.data import OOD_TASKS
    qi = world.query_indices(OOD_TASKS)
    texts = [world.queries[i].text for i in qi[:64]]
    return router, engine, texts


# ---------------------------------------------------------------------------
# the plan itself: determinism, validation, round-trip
# ---------------------------------------------------------------------------


def test_fault_plan_generate_is_deterministic():
    a = FaultPlan.generate(seed=3).to_json()
    b = FaultPlan.generate(seed=3).to_json()
    assert a == b
    assert FaultPlan.generate(seed=4).to_json() != a
    # hit 1 stays clean for every generated site except the sidecar
    # (saved at most once per soak), so the happy path runs first
    for ev in FaultPlan.generate(seed=3).events:
        if ev.site != "semcache.sidecar":
            assert min(ev.hits) >= 2


def test_fault_plan_json_round_trip_and_from_spec(tmp_path):
    plan = FaultPlan.generate(seed=9, horizon=20)
    again = FaultPlan.from_json(plan.to_json())
    assert again.to_json() == plan.to_json()
    assert FaultPlan.from_spec("seed:9:20").to_json() == plan.to_json()
    p = tmp_path / "plan.json"
    p.write_text(json.dumps(plan.to_json()))
    assert FaultPlan.from_spec(str(p)).to_json() == plan.to_json()


def test_fault_event_validates_site_and_kind():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultEvent("engine.warp", "raise", (1,))
    with pytest.raises(ValueError, match="invalid at"):
        FaultEvent("engine.lex", "raise", (1,))


def test_fire_matches_hit_counts_and_records():
    plan = FaultPlan([FaultEvent("engine.dispatch", "raise", (2,))])
    with faults.armed(plan):
        assert faults.fire("engine.dispatch") is None        # hit 1: clean
        with pytest.raises(InjectedFault):
            faults.fire("engine.dispatch")                   # hit 2: boom
        assert faults.fire("engine.dispatch") is None        # hit 3: clean
    assert plan.fired == [("engine.dispatch", "raise", 2)]
    # disarmed: hooks are inert no matter the schedule
    assert faults.fire("engine.dispatch") is None


def test_degradation_ledger_counts_and_resets():
    faults.record_degraded("engine_retry")
    faults.record_degraded("engine_retry")
    faults.record_degraded("frame_too_large")
    assert faults.degraded_counts() == {"engine_retry": 2,
                                        "frame_too_large": 1}
    assert faults.degraded_total("engine_retry") == 2
    assert faults.degraded_total() == 3
    faults.reset_degraded()
    assert faults.degraded_counts() == {}


# ---------------------------------------------------------------------------
# engine: retry heals, watchdog kills hangs, bisect quarantines poison
# ---------------------------------------------------------------------------


def test_dispatch_raise_retry_heals_bit_identical(stack):
    router, _, texts = stack
    batch = texts[:8]
    _, ref, _ = router.route(batch)
    eng = RouterEngine(router, RouterEngineConfig(cache_size=64))
    plan = FaultPlan([FaultEvent("engine.dispatch", "raise", (1,))])
    with faults.armed(plan) as p:
        _, sel = eng.route_batch(batch)
    np.testing.assert_array_equal(np.asarray(ref), sel)
    assert p.fired == [("engine.dispatch", "raise", 1)]
    assert faults.degraded_counts().get("engine_retry", 0) >= 1


def test_watchdog_times_out_hang_and_retry_heals(stack):
    import dataclasses

    router, _, texts = stack
    batch = texts[8:16]
    eng = RouterEngine(router, RouterEngineConfig(cache_size=64))
    # warm on the fast path first (the one-off jit compile must not race
    # the watchdog window), then clear the cache so the armed route
    # dispatches again and arm the watchdog for the re-dispatch
    _, ref = eng.route_batch(batch)
    eng.cache.clear()
    eng.cfg = dataclasses.replace(eng.cfg, dispatch_timeout_s=2.0)
    plan = FaultPlan([FaultEvent("engine.dispatch", "hang", (1,),
                                 duration_s=6.0)])
    t0 = time.monotonic()
    with faults.armed(plan):
        _, sel = eng.route_batch(batch)
    assert time.monotonic() - t0 < 6.0, "watchdog never fired"
    np.testing.assert_array_equal(ref, sel)
    assert faults.degraded_counts().get("engine_retry", 0) >= 1


def test_poison_query_bisected_to_exact_quarantine(stack):
    router, _, texts = stack
    batch = texts[16:24]
    poison = batch[3]
    eng = RouterEngine(router, RouterEngineConfig(cache_size=64))
    plan = FaultPlan([], poison_texts=[poison])
    with faults.armed(plan):
        with pytest.raises(PoisonQueryError) as ei:
            eng.route_batch(batch)
    assert ei.value.indices == [3]
    assert ei.value.texts == [poison]
    dc = faults.degraded_counts()
    assert dc.get("engine_quarantine") == 1
    assert dc.get("engine_retry", 0) >= 2      # two failed attempts minimum
    # every survivor was cached during the bisect: re-routing them is
    # table-only work and bit-identical to the fault-free decisions
    survivors = [t for t in batch if t != poison]
    hits0 = eng.cache_stats.hits
    with faults.armed(plan):
        names_s, _ = eng.route_batch(survivors)
    assert eng.cache_stats.hits - hits0 == len(survivors)
    clean = RouterEngine(router, RouterEngineConfig(cache_size=0))
    names_ref, _ = clean.route_batch(survivors)
    assert names_s == names_ref


def test_batcher_fails_poisoned_future_and_routes_survivors(stack):
    router, _, texts = stack
    batch = texts[24:32]
    poison = batch[5]
    eng = RouterEngine(router, RouterEngineConfig(cache_size=64))
    plan = FaultPlan([], poison_texts=[poison])
    mb = MicroBatcher(eng, max_batch=8)
    with faults.armed(plan):
        futs = mb.submit_many(batch)
        mb.flush()
    with pytest.raises(PoisonQueryError):
        futs[5].result(timeout=30)
    survivors = [t for i, t in enumerate(batch) if i != 5]
    got = [futs[i].result(timeout=30).model
           for i in range(len(batch)) if i != 5]
    # survivor latents are cached (bit-identical), so the batcher's
    # re-route matches a clean route of the same surviving batch
    names_ref, _ = eng.route_batch(survivors)
    assert got == names_ref


# ---------------------------------------------------------------------------
# persistence: crash mid-save, bit rot, previous generation survives
# ---------------------------------------------------------------------------


def test_artifact_crash_leaves_previous_record_loadable(tmp_path):
    path = str(tmp_path / "art")
    save_artifact(path, {"w": np.arange(8, dtype=np.float32)},
                  meta={"gen": 1})
    plan = FaultPlan([FaultEvent("ckpt.write", "crash", (1,))])
    with faults.armed(plan):
        with pytest.raises(RuntimeError, match="injected crash"):
            save_artifact(path, {"w": np.zeros(8, np.float32)},
                          meta={"gen": 2})
    tree, meta = load_artifact(path)
    assert meta["gen"] == 1
    np.testing.assert_array_equal(tree["w"], np.arange(8, dtype=np.float32))


def test_artifact_corruption_raises_typed_and_is_counted(tmp_path):
    path = str(tmp_path / "art")
    plan = FaultPlan([FaultEvent("ckpt.write", "corrupt", (1,))])
    with faults.armed(plan):
        save_artifact(path, {"w": np.ones(4, np.float32)})
    with pytest.raises(ArtifactCorruptError, match="checksum mismatch"):
        load_artifact(path)
    assert faults.degraded_counts().get("artifact_checksum") == 1
    # a clean re-save heals the record and GC leaves exactly one blob
    save_artifact(path, {"w": np.full(4, 7.0, np.float32)}, meta={"gen": 3})
    tree, meta = load_artifact(path)
    assert meta["gen"] == 3
    blobs = [f for f in os.listdir(tmp_path)
             if f.startswith("art.") and f.endswith(".npz")]
    assert len(blobs) == 1


def test_router_save_crash_previous_generation_routes(stack, tmp_path):
    from repro.api import Router
    router, _, texts = stack
    d = str(tmp_path / "router")
    router.save(d)
    _, ref, _ = router.route(texts[:6])
    plan = FaultPlan([FaultEvent("ckpt.write", "crash", (1,))])
    with faults.armed(plan):
        with pytest.raises(RuntimeError, match="injected crash"):
            router.save(d)
    # the torn save is invisible: the directory still opens and routes
    # bit-identically to the router that wrote it
    reopened = Router.open(d)
    _, sel, _ = reopened.route(texts[:6])
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(sel))


# ---------------------------------------------------------------------------
# transport: oversized frames, resets, torn replies, idempotent replays
# ---------------------------------------------------------------------------


def test_frame_too_large_is_typed_and_keeps_connection(stack):
    router, engine, texts = stack
    cfg = ServiceConfig(max_frame_bytes=2048)
    with BackgroundServer(router, engine=engine, cfg=cfg) as srv:
        with ServiceClient(srv.host, srv.port, retries=0) as c:
            with pytest.raises(FrameTooLargeError):
                c.route("x" * 8192)
            # the oversized payload was drained: the stream is still
            # frame-aligned and the SAME connection keeps serving
            assert c.ping()["status"] == "ok"
            assert c.route(texts[0]).model
    assert faults.degraded_counts().get("frame_too_large") == 1


def test_client_survives_resets_with_no_duplicate_routes(stack):
    router, engine, texts = stack
    batch = texts[32:36]
    plan = FaultPlan([
        FaultEvent("protocol.frame", "reset", (2,)),
        FaultEvent("protocol.frame", "reset_post", (4,)),
        FaultEvent("protocol.frame", "torn_frame", (6,)),
    ])
    with BackgroundServer(router, engine=engine) as srv:
        with ServiceClient(srv.host, srv.port, retries=4,
                           backoff_s=0.01, timeout=15.0) as c:
            ref = [c.route(t).model for t in batch]       # clean pass
            base = c.stats()["completed"]
            with faults.armed(plan) as p:
                got = [c.route(t).model for t in batch]
            assert got == ref, "divergence under connection chaos"
            # reset_post routed BEFORE aborting; the retry must answer
            # from the idempotency cache, not route again — so exactly
            # one completion per request despite three killed
            # connections
            assert c.stats()["completed"] - base == len(batch)
            m = c.metrics()
    assert {(s, k) for s, k, _ in p.fired} == {
        ("protocol.frame", "reset"), ("protocol.frame", "reset_post"),
        ("protocol.frame", "torn_frame")}
    dc = faults.degraded_counts()
    assert dc.get("connection_reset") == 2    # reset + reset_post
    assert dc.get("torn_frame") == 1
    assert "router_degraded_total" in m
    assert 'path="connection_reset"' in m


def test_breaker_storm_applies_atomically(stack):
    router, engine, _ = stack
    svc = RouterService(router, engine=engine)
    name = router.pool.names[0]
    snap = router.pool.snapshot()
    i = snap.index_of(name)
    pol = snap.health_policy
    plan = FaultPlan([FaultEvent("service.outcome", "storm", (1,),
                                 repeat=pol.failure_threshold + 3)])
    try:
        with faults.armed(plan):
            info = svc.report_outcome(None, name, ok=False)
        assert info["state_after"] == "open"
        assert router.pool.snapshot().breaker[i] == BREAKER_OPEN
        assert faults.degraded_counts().get("outcome_storm") == 1
    finally:
        # demo pool is session-shared: walk the breaker back to closed
        # (cooldown elapsed + the policy's worth of successful probes)
        t = time.time() + pol.open_cooldown_s + 1.0
        for _ in range(max(pol.half_open_probes, 1)):
            router.pool.record_outcome(name, True, now=t)
    assert router.pool.snapshot().breaker[i] == BREAKER_CLOSED


# ---------------------------------------------------------------------------
# RouteLog: torn-tail recovery
# ---------------------------------------------------------------------------


def test_routelog_drops_exactly_the_torn_tail(tmp_path):
    p = str(tmp_path / "routes.jsonl")
    with RouteLog(p) as log:
        for t in ("alpha", "beta", "gamma"):
            log.append(t, model="m0", policy="balanced")
    # a crash mid-append leaves a torn, unterminated JSON fragment
    with open(p, "a", encoding="utf-8") as f:
        f.write('{"text": "delta", "mo')
    assert RouteLog.read_texts(p) == ["alpha", "beta", "gamma"]
    # a torn tail later terminated by garbage bytes is still skipped
    with open(p, "a", encoding="utf-8") as f:
        f.write("\n\x00\x7fnot json at all\n")
    assert RouteLog.read_texts(p) == ["alpha", "beta", "gamma"]
    # the recovered log keeps accepting appends, replay sees them
    with RouteLog(p) as log:
        log.append("epsilon")
    assert RouteLog.read_texts(p) == ["alpha", "beta", "gamma", "epsilon"]


def test_routelog_read_skips_non_record_lines(tmp_path):
    p = str(tmp_path / "routes.jsonl")
    with open(p, "w", encoding="utf-8") as f:
        f.write('{"text": "a"}\n')
        f.write('["not", "a", "dict"]\n')       # valid JSON, wrong shape
        f.write('{"model": "m0"}\n')            # record without a text
        f.write('{"text": "b"}\n{"text": "a"}\n')
    assert RouteLog.read_texts(p) == ["a", "b"]
    assert RouteLog.read_texts(p, limit=1) == ["a"]
    assert RouteLog.read_texts(str(tmp_path / "missing.jsonl")) == []


# ---------------------------------------------------------------------------
# the chaos soak: all five families, zero divergence on served routes
# ---------------------------------------------------------------------------


def test_chaos_soak_all_families_zero_divergence(stack, tmp_path):
    router, _, texts = stack
    soak = texts[36:48]
    # fault-free reference in the served shape: one request = one batch
    # (cost/latency normalization is batch-scoped)
    ref_names = [router.route([t])[0][0] for t in soak]
    art = str(tmp_path / "soak_art")
    save_artifact(art, {"w": np.arange(4.0)}, meta={"gen": 1})
    plan = FaultPlan([
        FaultEvent("engine.dispatch", "raise", (1,)),
        FaultEvent("engine.lex", "hang", (1,), duration_s=0.01),
        FaultEvent("ckpt.write", "crash", (1,)),
        FaultEvent("protocol.frame", "reset", (3,)),
        FaultEvent("service.outcome", "storm", (1,), repeat=4),
    ])
    # fresh engine so the soak traffic actually dispatches (the session
    # engine may already hold these latents)
    eng = RouterEngine(router, RouterEngineConfig(cache_size=256))
    with BackgroundServer(router, engine=eng) as srv:
        with ServiceClient(srv.host, srv.port, retries=4,
                           backoff_s=0.01, timeout=30.0) as c:
            with faults.armed(plan) as p:
                got = [c.route(t).model for t in soak]
                # ok=True storm: fires the breaker-flood path without
                # opening the session pool's breaker
                c.report_outcome(None, router.pool.names[0], ok=True)
                with pytest.raises(RuntimeError, match="injected crash"):
                    save_artifact(art, {"w": np.zeros(4)}, meta={"gen": 2})
    assert got == ref_names, "non-shed selections diverged under chaos"
    tree, meta = load_artifact(art)
    assert meta["gen"] == 1
    np.testing.assert_array_equal(tree["w"], np.arange(4.0))
    assert p.fired_families() == {"dispatch", "lex", "persistence",
                                  "transport", "breaker"}
    dc = faults.degraded_counts()
    assert dc.get("engine_retry", 0) >= 1
    assert dc.get("connection_reset", 0) >= 1
    assert dc.get("outcome_storm") == 1


# ---------------------------------------------------------------------------
# wire reconstruction of the typed quarantine error
# ---------------------------------------------------------------------------


def test_poison_error_reconstructs_from_wire_message():
    # _raise_for_status rebuilds typed errors as exc_cls(message): the
    # ctor must tolerate that shape instead of falling back to a bare
    # ServiceError
    e = PoisonQueryError("2 quarantined queries ...")
    assert e.indices == [] and e.texts == []
    assert "quarantined" in str(e)
    e2 = PoisonQueryError([1, 4], ["a", "b"])
    assert e2.indices == [1, 4] and e2.texts == ["a", "b"]
