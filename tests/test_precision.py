"""Precision tiers + AOT-exported dispatch (ISSUE 5).

Three contracts:

* ``bf16_recheck`` — selections BIT-IDENTICAL to ``Router.route`` on the
  full test corpus for every policy (the margin-based fp32 re-check is
  calibrated so a bf16-induced error can never flip an argmax or a
  length-bin);
* ``bf16`` — no exactness guarantee, but a measured agreement floor with
  the f32 selections (and exact score agreement on the safe paths);
* AOT export — a WARM ``Router.open(dir, warmup=…)`` in a fresh process
  dispatches every scoring program from the ExportedStore without a
  single Python re-trace (engine trace counters stay zero), and the
  store survives fingerprint checks / degrades safely on mismatch.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.router import POLICIES
from repro.serving import RouterEngine, RouterEngineConfig
from repro.serving.cache import ExportedStore


@pytest.fixture(scope="module")
def corpus(demo_stack):
    world, router, _ = demo_stack
    from repro.data import ID_TASKS, OOD_TASKS

    qi = np.concatenate([world.query_indices(OOD_TASKS),
                         world.query_indices(ID_TASKS)])
    return world, router, [world.queries[i].text for i in qi]


# ---------------------------------------------------------------------------
# bf16_recheck: exact selection parity
# ---------------------------------------------------------------------------


def test_bf16_recheck_selections_bit_identical_all_policies(corpus):
    _, router, texts = corpus
    engine = RouterEngine(router, RouterEngineConfig(
        cache_size=0, precision="bf16_recheck", bf16_bulk=True))
    for pol in POLICIES:
        _, sel_ref, _ = router.route(texts, policy=pol)
        _, sel = engine.route_batch(texts, policy=pol)
        np.testing.assert_array_equal(np.asarray(sel_ref), sel,
                                      err_msg=f"policy {pol}")
        frac = engine.last_recheck_fraction
        assert frac is not None and 0.0 <= frac < 1.0, \
            "re-check should resolve a strict subset of the batch"


def test_bf16_recheck_parity_with_custom_weights_and_cache(corpus):
    """Parity must hold through the latent cache too — including the
    second pass, where re-checked queries come back as upgraded f32
    entries and the rest stay bf16."""
    _, router, texts = corpus
    engine = RouterEngine(router, RouterEngineConfig(
        cache_size=4 * len(texts), precision="bf16_recheck",
        bf16_bulk=True))
    w = (0.45, 0.45, 0.10)
    _, sel_ref, _ = router.route(texts, weights=w)
    for _ in range(2):                      # cold, then cache-warm
        _, sel = engine.route_batch(texts, weights=w)
        np.testing.assert_array_equal(np.asarray(sel_ref), sel)


def test_bf16_recheck_reported_in_batch_decision(corpus):
    _, router, texts = corpus
    engine = RouterEngine(router, RouterEngineConfig(
        cache_size=0, precision="bf16_recheck", bf16_bulk=True))
    dec = engine.route_pinned(texts[:32])
    assert dec.recheck_fraction is not None
    assert 0.0 <= dec.recheck_fraction <= 1.0
    # the f32 tier reports no re-check fraction
    e32 = RouterEngine(router, RouterEngineConfig(cache_size=0))
    assert e32.route_pinned(texts[:8]).recheck_fraction is None


def test_bf16_recheck_safe_paths_stay_f32(corpus):
    """score_queries / route diagnostics / constrained routing under the
    re-check tier score at f32 — bit-for-bit with the f32 engine."""
    _, router, texts = corpus
    tier = RouterEngine(router, RouterEngineConfig(
        cache_size=0, precision="bf16_recheck", bf16_bulk=True))
    base = RouterEngine(router, RouterEngineConfig(cache_size=0))
    for a, b in zip(tier.score_queries(texts[:24]),
                    base.score_queries(texts[:24])):
        np.testing.assert_array_equal(a, b)


def test_recheck_upgrades_cache_entries(corpus):
    """A re-checked query's cache entry is replaced by the f32 result, so
    later lookups (any tier) serve full precision."""
    _, router, texts = corpus
    engine = RouterEngine(router, RouterEngineConfig(
        cache_size=1024, precision="bf16_recheck", bf16_bulk=True))
    engine.route_batch(texts)
    precs = {engine.cache._data[t].precision
             for t in texts if t in engine.cache}
    assert "bf16" in precs, "bulk tier should leave bf16 entries"
    n_f32 = sum(1 for t in set(texts)
                if t in engine.cache
                and engine.cache._data[t].precision == "f32")
    assert engine.last_recheck_fraction == 0 or n_f32 > 0, \
        "re-checked queries must upgrade their entries to f32"


# ---------------------------------------------------------------------------
# pure bf16: measured agreement floor
# ---------------------------------------------------------------------------


def test_pure_bf16_agreement_floor(corpus):
    _, router, texts = corpus
    engine = RouterEngine(router, RouterEngineConfig(
        cache_size=0, precision="bf16"))
    for pol in POLICIES:
        _, sel_ref, _ = router.route(texts, policy=pol)
        _, sel = engine.route_batch(texts, policy=pol)
        agree = float(np.mean(np.asarray(sel_ref) == sel))
        assert agree >= 0.9, f"policy {pol}: agreement {agree:.3f} < 0.9"
        assert engine.last_recheck_fraction is None


def test_bf16_latents_close_to_f32(corpus):
    """The bf16 tier's predicted accuracies stay inside the calibrated
    re-check envelope — the property the margin defaults rely on."""
    _, router, texts = corpus
    e32 = RouterEngine(router, RouterEngineConfig(cache_size=0))
    e16 = RouterEngine(router, RouterEngineConfig(cache_size=0,
                                                  precision="bf16"))
    p32, _, _, s32, _ = e32._score_parts(texts, e32._pool())
    p16, _, _, s16, _ = e16._score_parts(texts, e16._pool())
    cfg = RouterEngineConfig()
    assert np.max(np.abs(p32 - p16)) < cfg.recheck_margin
    rel = np.max(np.abs(s32 - s16) / np.maximum(1.0, np.abs(s32)))
    assert rel < cfg.recheck_s_tol


def test_invalid_precision_rejected(corpus):
    _, router, _ = corpus
    with pytest.raises(ValueError, match="precision"):
        RouterEngine(router, RouterEngineConfig(precision="fp8"))


def test_bf16_bulk_backend_gate_scores_exactly(corpus):
    """With the default backend gate (None → bf16 bulk on TPU only), a
    bf16_recheck engine on this CPU container resolves its bulk pass to
    f32: selections AND scores are bit-for-bit the f32 engine's, the
    re-check is a no-op (fraction 0.0), and no bf16 weight copy is ever
    uploaded."""
    import jax

    _, router, texts = corpus
    gated = RouterEngine(router, RouterEngineConfig(
        cache_size=0, precision="bf16_recheck"))
    base = RouterEngine(router, RouterEngineConfig(cache_size=0))
    if jax.default_backend() == "tpu":      # gate resolves the other way
        pytest.skip("backend gate enables the bf16 bulk pass on TPU")
    assert "bf16" not in gated._params
    _, sel_ref = base.route_batch(texts[:32])
    _, sel = gated.route_batch(texts[:32])
    np.testing.assert_array_equal(sel_ref, sel)
    assert gated.last_recheck_fraction == 0.0


# ---------------------------------------------------------------------------
# AOT export: warm reopen re-traces nothing (fresh subprocesses)
# ---------------------------------------------------------------------------

_REOPEN_CHILD = """\
import sys, time, json
t0 = time.perf_counter()
from repro.api import Router
r = Router.open(sys.argv[1], warmup=int(sys.argv[2]), compile_cache=True)
e = r.engine()
texts = ["aot reopen smoke query", "another, longer smoke query for the bucket ladder"]
names, sel, _ = r.route(texts)
names2, sel2 = e.route_batch(texts)
assert list(sel) == list(sel2), (sel, sel2)
print("CHILD=" + json.dumps({
    "warmup_s": r.calibration["warmup_s"],
    "traces": e.trace_counts,
    "exported": len(e._exported),
    "total_s": time.perf_counter() - t0,
}))
"""


@pytest.mark.slow
def test_warm_reopen_uses_exports_no_retrace(corpus, tmp_path_factory):
    """Two fresh subprocesses share one artifact dir: the first (cold)
    exports + compiles every rung; the second (warm) must deserialize the
    exported programs and perform ZERO per-shape re-traces of the scoring
    programs — and still route identically to the reference path."""
    _, router, _ = corpus
    art_dir = str(tmp_path_factory.mktemp("aot_artifact"))
    router.save(art_dir)

    def reopen():
        out = subprocess.run(
            [sys.executable, "-c", _REOPEN_CHILD, art_dir, "4"],
            capture_output=True, text=True, timeout=900,
            env=os.environ.copy())
        for line in out.stdout.splitlines():
            if line.startswith("CHILD="):
                import json

                return json.loads(line[len("CHILD="):])
        raise AssertionError(
            f"child failed (rc={out.returncode}): {out.stderr[-2000:]}")

    cold = reopen()
    warm = reopen()
    assert cold["exported"] > 0 and warm["exported"] == cold["exported"]
    assert sum(cold["traces"].values()) > 0, \
        "cold reopen must trace (it creates the exports)"
    assert warm["traces"] == {}, \
        f"warm reopen re-traced scoring programs: {warm['traces']}"
    assert warm["warmup_s"] < cold["warmup_s"], \
        "exported-program warmup should beat the tracing one"


def test_exported_store_fingerprint_invalidation(tmp_path):
    """A stale fingerprint reads as empty (stale constants can never be
    served); a matching one round-trips the program."""
    import jax
    import jax.numpy as jnp
    from jax import export as jax_export

    exported = jax_export.export(jax.jit(lambda x: x * 2.0))(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    store = ExportedStore(str(tmp_path), "fp-a")
    store.save("prog", exported)
    again = ExportedStore(str(tmp_path), "fp-a")
    assert len(again) == 1
    loaded = again.load("prog")
    assert loaded is not None
    np.testing.assert_array_equal(
        np.asarray(jax.jit(loaded.call)(jnp.ones(4, jnp.float32))),
        np.full(4, 2.0, np.float32))
    blob = os.path.join(str(tmp_path), again._entries["prog"])
    assert os.path.exists(blob)
    stale = ExportedStore(str(tmp_path), "fp-b")
    assert len(stale) == 0 and stale.load("prog") is None
    # the stale generation's blob is unreachable — it must be deleted,
    # not accumulated across re-calibrations
    assert not os.path.exists(blob)


def test_exported_store_corrupt_blob_degrades_to_none(tmp_path):
    import jax
    import jax.numpy as jnp
    from jax import export as jax_export

    exported = jax_export.export(jax.jit(lambda x: x + 1.0))(
        jax.ShapeDtypeStruct((2,), jnp.float32))
    store = ExportedStore(str(tmp_path), "fp")
    store.save("prog", exported)
    blob_path = os.path.join(str(tmp_path), store._entries["prog"])
    with open(blob_path, "wb") as f:
        f.write(b"not a stablehlo artifact")
    assert ExportedStore(str(tmp_path), "fp").load("prog") is None
