"""BAD fixture (bare-except, swallowed-exception): a serving-plane
worker absorbing failures invisibly.  The test maps this under
``src/repro/serving/``.  Parsed only, never imported.
"""


def route_chunk(engine, texts):
    try:
        return engine.compute(texts)
    except:                       # BAD: bare — eats KeyboardInterrupt too
        return None


def flush(cache, path):
    try:
        cache.write(path)
    except Exception:             # BAD: swallowed — no trace anywhere
        pass


def drain(sock):
    try:
        return sock.recv(4096)
    except (ValueError, BaseException):   # BAD: broad via tuple, silent
        return b""


def fan_back(fut, engine, text):
    try:
        fut.set_result(engine.route(text))
    except Exception as exc:      # ok: fanned back into the future
        fut.set_exception(exc)
