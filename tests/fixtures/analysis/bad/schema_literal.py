"""BAD fixture (schema-version-literal): a caller hard-coding
``schema_version`` ints in a module that defines no schema constant —
all three literal shapes the rule covers.  Parsed only, never imported.
"""


def save(path, rows):
    rec = {"schema_version": 2, "rows": rows}   # BAD: dict literal
    rec["schema_version"] = 3                   # BAD: subscript store
    write_record(path, rec, schema_version=1)   # BAD: keyword arg
