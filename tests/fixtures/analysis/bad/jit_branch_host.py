"""BAD fixture (jit-branch-on-traced, jit-host-call): every jit idiom
the checker understands, each committing a trace-time sin.  Parsed only,
never imported.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def clamp(x, lo):
    if x > lo:              # BAD: Python `if` on a traced argument
        return lo
    return x


@functools.partial(jax.jit, static_argnames=("k",))
def top_scores(scores, k):
    while scores > 0:       # BAD: `while` on a traced argument
        scores = scores - 1
    best = np.sort(scores)  # BAD: host numpy inside the traced body
    print("traced!")        # BAD: fires at trace time only
    return best[:k]


def _scale(x, gain):
    return x * gain


scale_jit = jax.jit(_scale)  # wrap form: body above is traced too
