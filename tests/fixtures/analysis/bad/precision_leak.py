"""BAD fixture (precision-dtype): stray low-precision casts in the
scoring stack — attribute dtypes, dtype strings, and dtype= keywords.
The test maps this under ``src/repro/core/``.  Parsed only, never
imported.
"""
import jax.numpy as jnp
import numpy as np


def rescore(x, feats):
    y = x.astype(jnp.bfloat16)          # BAD: attribute dtype
    z = feats.astype("float16")         # BAD: dtype string to astype
    acc = jnp.zeros(4, dtype="bfloat16")    # BAD: dtype= string
    h = np.float16(0.5)                 # BAD: attribute dtype
    return y, z, acc, h
