"""BAD fixture (async-blocking-call, async-global-state,
monotonic-time): an event-loop handler committing every async-safety
sin.  The test maps this under ``src/repro/serving/``.  Parsed only,
never imported.
"""
import socket
import subprocess
import time

_HITS = 0


async def handle(conn, payload):
    global _HITS            # BAD: anonymous shared state from a handler
    _HITS += 1
    started = time.time()   # BAD: wall clock for an interval
    time.sleep(0.01)        # BAD: blocks the loop
    raw = open("/tmp/x")    # BAD: blocking file IO
    peer = socket.create_connection(("h", 1))   # BAD
    peer.sendall(payload)   # BAD: blocking socket primitive
    subprocess.run(["true"])                    # BAD
    client = ServiceClient("h", 1)              # BAD: sync transport
    return time.time() - started                # BAD again


async def fine(conn):
    def _sync_helper():
        # excluded: nested sync defs run wherever they are called
        time.sleep(0.0)
    return _sync_helper
