"""BAD fixture (direct-state-write): replica lifecycle state mutated
outside the supervisor — skips the legality table and the audit trail.
The test maps this under ``src/repro/serving/``.  Parsed only, never
imported.
"""
import enum


class ReplicaState(enum.IntEnum):
    STARTING = 0
    HEALTHY = 1
    SUSPECT = 2
    DEAD = 3


def kill(rep):
    rep._state = ReplicaState.DEAD        # BAD: free function writes slot


def recover(rep):
    rep.state = ReplicaState.HEALTHY      # BAD: public spelling too


class HeartbeatLoop:
    def __init__(self, replicas):
        self.replicas = replicas

    def tick(self, now):
        for rep in self.replicas:
            if now - rep.last_beat > 1.0:
                rep._state = ReplicaState.SUSPECT   # BAD: not supervisor


class ReplicaSupervisor:
    def _transition(self, rep, to, reason):
        rep._state = to                   # ok: inside the supervisor
