"""BAD fixture (schema-migration-chain): a schema module whose version
constant was bumped to 3 while the migration dict only covers v1 — v2
records on disk can no longer load.  Parsed only, never imported.
"""
POOL_SCHEMA_VERSION = 3


def _migrate_v1_to_v2(rec):
    rec["extra"] = None
    return rec


_POOL_MIGRATIONS = {
    1: _migrate_v1_to_v2,
    # BAD: no step for version 2
}
