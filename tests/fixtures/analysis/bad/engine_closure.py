"""BAD fixture (jit-closure-params): a trimmed copy of
``serving/engine.py``'s ``_build_jits`` with the PR-4 "params enter as
jit ARGUMENTS" pattern deleted — ``_latents`` reads ``pred.params`` from
closure state, so every persistent compile-cache entry would embed the
full weight pytree.  The test maps this file to
``src/repro/serving/engine.py`` in a scratch tree and asserts the
jit-purity checker catches it.

Parsed only, never imported.
"""
import jax
import jax.numpy as jnp


class Engine:
    def _build_jits(self):
        art = self.router.artifacts
        pred = art.require_predictor()
        pc = pred.cfg
        clusters = pred.clusters
        mu, sd = (jnp.asarray(s, jnp.float32) for s in pred.feat_stats)

        def _latents(ids, mask, feats):
            # the deleted invariant: weights come from the enclosing
            # scope instead of entering as a jit argument
            e_se = encode(pred.params["enc"], ids, mask, pc)
            f = (feats - mu) / sd
            return apply_heads(pred.params["heads"], e_se, f, clusters,
                               pc.latent_dim)

        self._latents_jit = jax.jit(_latents)
