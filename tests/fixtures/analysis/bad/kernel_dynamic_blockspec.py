"""BAD fixture (kernel-blockspec-dynamic): BlockSpec tile shapes that
are not static host ints — a float literal and a non-whitelisted call.
Parsed only, never imported.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kern(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def halved_tiles(x, rows):
    return pl.pallas_call(
        _kern,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(2,),
        in_specs=[pl.BlockSpec((rows * 0.5, x.shape[1]),   # BAD: float
                               lambda i: (i, 0))],
        out_specs=pl.BlockSpec((pick_tile(x), x.shape[1]),  # BAD: call
                               lambda i: (i, 0)),
    )(x)
