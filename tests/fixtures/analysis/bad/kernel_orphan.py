"""BAD fixture (kernel-missing-ref / kernel-missing-parity-test): a
Pallas kernel module with no ``*_ref`` twin.  The test maps this file to
``src/repro/kernels/fancy_scan.py`` in a scratch tree — without a
``fancy_scan*_ref`` in ref.py it trips ``kernel-missing-ref``; with the
ref present but unreferenced by tests/test_kernels.py it trips
``kernel-missing-parity-test``.  Parsed only, never imported.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fancy_scan_kernel(x_ref, o_ref):
    o_ref[...] = jnp.cumsum(x_ref[...], axis=-1)


def fancy_scan_tpu(x, block_rows=128):
    n = x.shape[0]
    return pl.pallas_call(
        _fancy_scan_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(n // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, x.shape[1]),
                               lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, x.shape[1]),
                               lambda i: (i, 0)),
    )(x)
