"""GOOD fixture: the async-safe version of ``bad/async_service.py`` —
asyncio primitives, monotonic clocks, owned state.  Parsed only, never
imported.
"""
import asyncio
import time


class Handler:
    def __init__(self):
        self.hits = 0

    async def handle(self, reader, writer, payload):
        self.hits += 1
        started = time.perf_counter()
        await asyncio.sleep(0.01)
        writer.write(payload)
        await writer.drain()
        deadline = time.monotonic() + 1.0
        return time.perf_counter() - started, deadline
