"""GOOD fixture: the pure-jnp ``*_ref`` twin for ``kernel_orphan.py``.
The test maps this to ``src/repro/kernels/ref.py`` to build a scratch
tree where the kernel-contract checker is satisfied (or, with an empty
tests/test_kernels.py, trips only the parity-test rule).  Parsed only,
never imported.
"""
import jax.numpy as jnp


def fancy_scan_ref(x):
    return jnp.cumsum(x, axis=-1)
