"""GOOD fixture: a schema module whose migration chain fully covers the
version bump, writing the version through its own constant.  Parsed
only, never imported.
"""
DEMO_SCHEMA_VERSION = 3


def _v1_to_v2(rec):
    return rec


def _v2_to_v3(rec):
    return rec


_DEMO_MIGRATIONS = {1: _v1_to_v2, 2: _v2_to_v3}


def save(rec):
    rec["schema_version"] = DEMO_SCHEMA_VERSION  # constant, not a literal
    return rec
