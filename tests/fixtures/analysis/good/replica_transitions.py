"""GOOD fixture (replica-state-machine): every lifecycle edge goes
through the supervisor's audited ``_transition``.  The test maps this
under ``src/repro/serving/``.  Parsed only, never imported.
"""
import enum


class ReplicaState(enum.IntEnum):
    STARTING = 0
    HEALTHY = 1
    DEAD = 3


class Replica:
    # class-level default is a Name target, not an Attribute write —
    # the rule must NOT fire here
    _state: ReplicaState = ReplicaState.STARTING

    def __init__(self, name):
        self.name = name

    @property
    def state(self):
        return self._state


class ReplicaSupervisor:
    def __init__(self, replicas):
        self.replicas = replicas
        self.transitions = []

    def _transition(self, rep, to, reason):
        # the ONE sanctioned write site: inside the supervisor class
        rep._state = to
        self.transitions.append((rep.name, to, reason))

    def mark_dead(self, rep):
        self._transition(rep, ReplicaState.DEAD, "probe timeout")


def failover(sup, rep):
    # callers ask the supervisor; they never touch the attribute
    sup.mark_dead(rep)
