"""GOOD fixture: scoring-stack code that stays f32 — the dtype the
bit-exact selection guarantee assumes.  Parsed only, never imported.
"""
import jax.numpy as jnp


def rescore(x, feats):
    y = x.astype(jnp.float32)
    acc = jnp.zeros(4, dtype=jnp.float32)
    return y + acc, feats.astype("float64")
