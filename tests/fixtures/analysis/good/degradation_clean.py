"""GOOD fixture: the accounted-for version of
``bad/degradation_swallow.py`` — every broad handler leaves a trace
(degradation ledger, warning, fan-back, or typed re-raise).  Parsed
only, never imported.
"""
import warnings


def route_chunk(engine, texts, faults):
    try:
        return engine.compute(texts)
    except Exception:             # counted in the degradation ledger
        faults.record_degraded("engine_retry")
        return None


def flush(cache, path):
    try:
        cache.write(path)
    except OSError:               # narrow: naming the class IS the
        return None               # accounting


def load(path):
    try:
        return open(path, "rb").read()
    except Exception as exc:      # re-raised typed
        raise RuntimeError(f"artifact unreadable: {exc}") from exc


def probe(bank, sketch):
    try:
        return bank.lookup(sketch)
    except Exception:             # warned — visible to operators
        warnings.warn("semantic bank probe failed; cold path")
        return None
