"""GOOD fixture: jit bodies that honour every jit-purity rule — params
enter as arguments, branches are on static args or jnp primitives, no
host calls.  Parsed only, never imported.
"""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def score(params, feats):
    # weights as jit ARGUMENTS (the PR-4 invariant), jnp-only body
    return jnp.dot(feats, params["w"]) + params["b"]


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def dispatch(x, use_pallas):
    if use_pallas:          # fine: static_argnames makes this host-level
        return x * 2.0
    return jnp.where(x > 0, x, 0.0)


@functools.partial(jax.jit, static_argnums=(1,))
def tile(x, reps):
    if reps > 1:            # fine: static_argnums position 1
        return jnp.tile(x, reps)
    return x


def _affine(params, x):
    return x @ params["w"]


affine_jit = jax.jit(_affine)  # wrap form, params still an argument
