"""Zero-shot model onboarding (paper Eq. 5)."""
import jax.numpy as jnp
import numpy as np

from repro.core.anchors import greedy_doptimal
from repro.core.profiling import ProfilingConfig, predict_accuracy, profile_new_model


def test_theta_recovery_noiseless():
    """With expected (soft) responses, BCE fitting recovers θ accurately."""
    rng = np.random.default_rng(0)
    D, N = 8, 200
    alpha = jnp.asarray(np.abs(rng.normal(1, 0.4, (N, D))), jnp.float32)
    b = jnp.asarray(rng.normal(0, 1, (N, D)), jnp.float32)
    theta_true = jnp.asarray(rng.normal(0, 1, D), jnp.float32)
    p_true = predict_accuracy(theta_true, alpha, b)
    theta_hat, diag = profile_new_model(alpha, b, p_true,
                                        ProfilingConfig(l2=0.0, steps=800))
    p_hat = predict_accuracy(theta_hat, alpha, b)
    assert float(jnp.mean(jnp.abs(p_hat - p_true))) < 0.02


def test_bce_decreases():
    rng = np.random.default_rng(1)
    D, N = 6, 80
    alpha = jnp.asarray(np.abs(rng.normal(1, 0.4, (N, D))), jnp.float32)
    b = jnp.asarray(rng.normal(0, 1, (N, D)), jnp.float32)
    y = jnp.asarray((rng.random(N) < 0.6).astype(np.float32))
    _, diag = profile_new_model(alpha, b, y)
    tr = np.asarray(diag["bce_trace"])
    assert tr[-1] <= tr[0] + 1e-6


def test_onboarding_from_anchors(calibrated):
    """Profiling a held-out model from D-optimal anchors predicts its
    success probabilities on ALL prompts."""
    world, qi = calibrated["world"], calibrated["qi"]
    pm = calibrated["post"]
    A, B = pm["alpha"], pm["b"]
    idx = np.asarray(greedy_doptimal(A, 100))
    m = world.model_index("future-model-00")
    y = world.sample_responses([m], qi, seed=0)[0]
    theta_hat, _ = profile_new_model(A[idx], B[idx], jnp.asarray(y[idx]))
    p_hat = np.asarray(predict_accuracy(theta_hat, A, B))
    p_true = world.true_prob([m], qi)[0]
    corr = np.corrcoef(p_hat, p_true)[0, 1]
    assert corr > 0.45, f"onboarded-model accuracy prediction weak: {corr:.3f}"
